"""Async-round benchmark: round-time and bytes-to-target vs straggler rate,
sync vs async (staleness-1 admission), plus the overlapped-collectives
micro-benchmark.

Because CI wall-clock is too noisy to carry the scheduling claim, round time
comes from an explicit latency model (constants below, normalised units):

    sync  round = (T_TIMEOUT if any straggler else T_COMPUTE) + T_DECODE
                  — the server waits for stragglers until the timeout, then
                  drops them, then decodes
    async round = max(T_COMPUTE, T_DECODE)
                  — the server decodes whoever reported at the deadline while
                  clients already encode the next round (steady state; round
                  0 pays one extra T_DECODE to fill the pipe)

The MSE trajectories are NOT modelled: both modes run the real round driver
(``fl.rounds.run_rounds``) on the same cohort draws, so the quality side of
wall-clock-per-target-MSE is measured, and the ledger identity
``async_total_bytes == sync_total_bytes + admitted_stale_bytes`` is asserted
(the byte cost of admission is exactly the admitted payloads).

Rows:
    async/<task>@<rate>/<mode>   us_per_round   time_to_target=<model units>;
        bytes_to_target=<...>;mean_mse_pop=<...>;stale=<n>
    async/overlap/<pipeline>     us_per_call    parity=bit-exact;tiles=<C>

The run asserts the tentpole acceptance: at straggler rate >= 0.2 the async
driver strictly reduces modelled wall-clock-to-target-MSE vs sync.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

from .common import rows

T_COMPUTE = 1.0   # client vector compute + encode
T_DECODE = 0.5    # server decode
T_TIMEOUT = 3.0   # how long the sync server waits before dropping stragglers


def round_times(hist, mode: str) -> np.ndarray:
    """Per-round wall-clock under the latency model, from the real
    participation outcomes recorded in ``hist``."""
    straggled = np.asarray(hist.n_sampled) > np.asarray(hist.n_survivors)
    if mode == "sync":
        return np.where(straggled, T_TIMEOUT, T_COMPUTE) + T_DECODE
    t = np.full(len(hist.mse), max(T_COMPUTE, T_DECODE))
    t[0] += T_DECODE  # pipeline fill
    return t


def to_target(hist, times: np.ndarray, target: float):
    """(modelled time, ledger bytes) at the round where the RUNNING MEAN of
    mse_pop first reaches <= target — one trajectory for both columns, so a
    row can never report a finite time next to bytes=never."""
    run_mean = np.cumsum(hist.mse_pop) / np.arange(1, len(hist.mse_pop) + 1)
    hit = np.flatnonzero(run_mean <= target)
    if not len(hit):
        return None, None
    r = hit[0]
    return float(np.cumsum(times)[r]), int(np.cumsum(hist.bytes)[r])


def compare(out, rate: float, n_rounds: int, d: int, seed: int = 0):
    """One straggler rate: sync vs async on the drift task, same cohort
    draws. Returns (sync_time_to_target, async_time_to_target)."""
    task = get_task("drift", n_clients=8, d=d, rho=0.95, omega=0.02, seed=seed)
    pipe = codec.RandProjSpatial(k=max(1, d // 10), d_block=d, transform="avg")
    cohort = Cohort(n_clients=8, dropout=rate)

    hists, walls = {}, {}
    for mode in ("sync", "async"):
        cfg = RoundConfig(n_rounds=n_rounds, seed=seed,
                          async_rounds=(mode == "async"))
        t0 = time.time()
        _, hist = run_rounds(task, pipe, cohort, cfg)
        us_round = (time.time() - t0) / n_rounds * 1e6
        hists[mode], walls[mode] = hist, us_round

    # ledger identity: async extra cost is exactly the late-arrival bytes
    h_s, h_a = hists["sync"], hists["async"]
    if h_a.total_bytes != h_s.total_bytes + h_a.total_stale_bytes:
        raise AssertionError(
            f"async ledger mismatch at rate {rate}: "
            f"{h_a.total_bytes} != {h_s.total_bytes} + {h_a.total_stale_bytes}"
        )

    # target both runs reach: 5% above the sync steady-state running mean
    run_mean_sync = np.cumsum(h_s.mse_pop) / np.arange(1, n_rounds + 1)
    target = 1.05 * float(run_mean_sync[-1])

    out_times = {}
    for mode in ("sync", "async"):
        hist = hists[mode]
        times = round_times(hist, mode)
        ttt, btt = to_target(hist, times, target)
        out_times[mode] = ttt
        rows(out, f"async/drift@{rate:.1f}/{mode}", walls[mode],
             f"time_to_target={'never' if ttt is None else f'{ttt:.1f}'};"
             f"bytes_to_target={'never' if btt is None else btt};"
             f"mean_mse_pop={np.mean(hist.mse_pop):.6f};"
             f"stale={sum(hist.n_stale)};total_time={np.sum(times):.1f}")
    return out_times["sync"], out_times["async"]


def assert_async_wins(rate: float, t_sync, t_async) -> None:
    """Tentpole acceptance: at straggler rate >= 0.2 async strictly reduces
    modelled wall-clock-to-target-MSE."""
    if rate < 0.2:
        return
    if t_sync is None:
        return  # sync never reached its own steady state: nothing to compare
    if t_async is None or not t_async < t_sync:
        raise AssertionError(
            f"async did not strictly beat sync at straggler rate {rate}: "
            f"sync={t_sync} async={t_async}"
        )


def overlap_microbench(out, n=8, d=256, n_chunks=8):
    """Overlapped vs synchronous collectives: same bytes, same bits; CPU
    timing recorded for the trajectory only (the overlap pays off on async
    backends where dispatch order buys concurrency)."""
    import jax
    import jax.numpy as jnp

    from repro.dist import collectives

    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(
        rng.standard_normal((n, n_chunks, d)).astype(np.float32))}
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=d // 8, d_block=d))
    key = jax.random.key(0)
    results = {}
    for overlap in (False, True):
        m, info, _ = collectives.compressed_mean_tree(  # untimed warmup
            pipe, key, tree, overlap=overlap)
        jax.block_until_ready(m)
        t0 = time.time()
        m, info, _ = collectives.compressed_mean_tree(
            pipe, key, tree, overlap=overlap)
        jax.block_until_ready(m)
        us = (time.time() - t0) * 1e6
        results[overlap] = (m, info)
        rows(out, f"async/overlap/rand_proj_spatial.{'stream' if overlap else 'sync'}",
             us, f"parity=bit-exact;tiles={n_chunks};"
                 f"bytes_per_client={info['payload_bytes_per_client']}")
    np.testing.assert_array_equal(np.asarray(results[False][0]["w"]),
                                  np.asarray(results[True][0]["w"]))
    assert results[False][1] == results[True][1]


def run(out, n_rounds=30, d=256):
    for rate in (0.0, 0.2, 0.4):
        t_sync, t_async = compare(out, rate, n_rounds, d)
        assert_async_wins(rate, t_sync, t_async)
    overlap_microbench(out)


def smoke(out):
    """Reduced CI sweep: one clean rate + one straggler rate, with the
    strict async-wins acceptance assert kept live."""
    for rate in (0.0, 0.3):
        t_sync, t_async = compare(out, rate, n_rounds=12, d=128)
        assert_async_wins(rate, t_sync, t_async)
    overlap_microbench(out, n=4, d=128, n_chunks=4)
