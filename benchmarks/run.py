"""Benchmark harness: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only mse,tasks,systems,roofline]

Prints ``name,us_per_call,derived`` CSV (and tees a copy to
results/bench_output.csv).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="mse,tasks,systems,roofline")
    args = ap.parse_args()
    sections = set(args.only.split(","))

    out: list[str] = ["name,us_per_call,derived"]
    t0 = time.time()
    if "mse" in sections:
        from . import bench_mse

        bench_mse.run(out)
    if "tasks" in sections:
        from . import bench_tasks

        bench_tasks.run(out)
    if "systems" in sections:
        from . import bench_systems

        bench_systems.run(out)
    if "roofline" in sections:
        from . import roofline

        roofline.run(out)

    print("\n".join(out))
    os.makedirs(os.path.join(os.path.dirname(__file__), "..", "results"), exist_ok=True)
    with open(os.path.join(os.path.dirname(__file__), "..", "results", "bench_output.csv"), "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"# total {time.time()-t0:.1f}s, {len(out)-1} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
