"""Benchmark harness: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only mse,tasks,fl,systems,roofline]
    PYTHONPATH=src python -m benchmarks.run --smoke   # CI: reduced sizes

Prints ``name,us_per_call,derived`` CSV (teed to results/bench_output.csv)
and writes the same rows as ``results/BENCH_<mode>.json`` so CI can archive
the perf trajectory as a workflow artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def smoke(out: list[str]) -> None:
    """Reduced-size sweep for CI: small (n, k, d), few trials, plus a
    round-trip through the dist layer's compressed-mean collective."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import codec
    from repro.dist import collectives

    from . import bench_systems
    from .common import base_vector_clients, mse_over_trials, rows, timed

    d, n, k = 256, 8, 16
    xs, r = base_vector_clients(n, d, 3, seed=0)
    for name, tf in [("rand_k", "one"), ("rand_k_spatial", "avg"),
                     ("rand_proj_spatial", "avg")]:
        spec = codec.build(name, k=k, d_block=d, transform=tf)
        mse, sec = mse_over_trials(spec, xs, trials=20)
        rows(out, f"smoke/mse_R{r:.1f}/n{n}_k{k}/{name}", sec * 1e6, f"{mse:.4f}")

    bench_systems.walltime(out, n=4, k=16, d=256)
    bench_systems.ownership(out, n=8, k=64, d=128, n_chunks=8)
    bench_systems.fused_kernels(out, n=8, k=32, d=512, n_chunks=4)
    bench_systems.sparseproj_encode(out)  # full-size: the gate needs margin
    bench_systems.quant(out)  # full-size: the MSE + coded<=raw gates need margin

    from . import bench_fl

    bench_fl.smoke(out)

    from . import bench_async

    bench_async.smoke(out)

    # dist-layer round-trip: pytree -> chunked encode -> server decode -> tree
    rng = np.random.default_rng(0)
    tree = {
        "w": jnp.asarray(rng.standard_normal((n, 64, 64)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((n, 96)), jnp.float32),
    }
    for payload_dtype in ("float32", "int8"):
        spec = codec.build("rand_proj_spatial", k=32, d_block=256,
                             transform="avg", payload_dtype=payload_dtype)
        _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), tree)
        fn = jax.jit(
            lambda key, s=spec: collectives.compressed_mean_tree(s, key, tree)[0]
        )
        sec, _ = timed(fn, jax.random.key(0))
        rows(out, f"smoke/dist/compressed_mean_tree/{payload_dtype}", sec * 1e6,
             f"bytes_per_client={info['payload_bytes_per_client']};"
             f"ratio={info['full_bytes'] / info['payload_bytes_per_client']:.1f}x")


def run_metadata(mode: str) -> dict:
    """The provenance stamp every benchmark artifact carries (schema v1):
    enough to reproduce the run and to refuse to compare apples to oranges
    across jax versions / backends / hosts. tools/bench_artifacts.py
    validates its presence before CI uploads anything."""
    import platform

    import jax

    return {
        "mode": mode,
        "jax_version": jax.__version__,
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def write_json(out: list[str], mode: str, secs: float) -> str:
    records = []
    for line in out[1:]:
        name, us, derived = line.split(",", 2)
        records.append({"name": name, "us_per_call": float(us), "derived": derived})
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{mode}.json")
    with open(path, "w") as f:
        json.dump(
            {"schema_version": 1, "mode": mode, "run": run_metadata(mode),
             "total_s": round(secs, 1), "rows": records},
            f, indent=1,
        )
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="mse,tasks,fl,async,systems,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-size CI sweep; writes results/BENCH_smoke.json")
    args = ap.parse_args()
    sections = set(args.only.split(","))

    out: list[str] = ["name,us_per_call,derived"]
    t0 = time.time()
    if args.smoke:
        smoke(out)
    else:
        if "mse" in sections:
            from . import bench_mse

            bench_mse.run(out)
        if "tasks" in sections:
            from . import bench_tasks

            bench_tasks.run(out)
        if "fl" in sections:
            from . import bench_fl

            bench_fl.run(out)
        if "async" in sections:
            from . import bench_async

            bench_async.run(out)
        if "systems" in sections:
            from . import bench_systems

            bench_systems.run(out)
        if "roofline" in sections:
            from . import roofline

            roofline.run(out)

    print("\n".join(out))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "bench_output.csv"), "w") as f:
        f.write("\n".join(out) + "\n")
    secs = time.time() - t0
    path = write_json(out, "smoke" if args.smoke else "full", secs)
    print(f"# total {secs:.1f}s, {len(out)-1} rows -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
