"""Paper Fig. 4: distributed power iteration, distributed k-means,
distributed linear regression — synthetic stand-ins for Fashion-MNIST /
UJIndoor (offline container; same d, n, k regimes, IID + non-IID splits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec, mean_estimate

from .common import rows, timed

ESTIMATORS = [
    ("rand_k", dict()),
    ("rand_k_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="avg")),
    ("wangni", dict()),
    ("induced", dict()),
]


def _image_like_data(n_samples, d, seed=0, non_iid=False, n_clients=10):
    """Low-rank + structured noise, Fashion-MNIST-like second moment."""
    rng = np.random.default_rng(seed)
    rank = 16
    basis = rng.standard_normal((rank, d)) * (1.0 / np.sqrt(d))
    scale = np.geomspace(3.0, 0.3, rank)[:, None]
    z = rng.standard_normal((n_samples, rank))
    labels = rng.integers(0, 10, n_samples)
    cls_shift = rng.standard_normal((10, d)) * 0.4 / np.sqrt(d)
    x = z @ (basis * scale) + cls_shift[labels] + rng.standard_normal((n_samples, d)) * 0.05
    if non_iid:
        order = np.argsort(labels)  # label-sorted shards (paper App. D)
        x, labels = x[order], labels[order]
    return x.astype(np.float32), labels


def _split(x, n_clients):
    per = x.shape[0] // n_clients
    return np.stack([x[i * per:(i + 1) * per] for i in range(n_clients)])


def power_iteration(out, n=10, k=102, d=1024, iters=15, non_iid=False):
    x, _ = _image_like_data(4000, d, non_iid=non_iid, n_clients=n)
    shards = jnp.asarray(_split(x, n))  # (n, m, d)
    cov = x.T @ x / x.shape[0]
    v_top = np.linalg.eigh(cov)[1][:, -1]
    tag = "noniid" if non_iid else "iid"

    for name, kw in ESTIMATORS + [("identity", {})]:
        spec = codec.build(name, k=k, d_block=d, **kw)

        @jax.jit
        def one_round(v, key):
            local = jnp.einsum("nmd,d->nm", shards, v)
            vi = jnp.einsum("nmd,nm->nd", shards, local)
            vi = vi / (jnp.linalg.norm(vi, axis=1, keepdims=True) + 1e-9)
            vh = mean_estimate(spec, key, vi[:, None, :])[0]
            return vh / (jnp.linalg.norm(vh) + 1e-9)

        def run():
            v = jnp.ones(d) / jnp.sqrt(d)
            for t in range(iters):
                v = one_round(v, jax.random.fold_in(jax.random.key(7), t))
            return v

        sec, v = timed(run, warmup=0, iters=1)
        err = min(float(jnp.linalg.norm(v - v_top)), float(jnp.linalg.norm(v + v_top)))
        rows(out, f"fig4/power_iter_{tag}/n{n}_k{k}/{name}", sec / iters * 1e6, f"{err:.4f}")


def kmeans(out, n=10, k=102, d=1024, iters=10, n_clusters=10, non_iid=False):
    x, _ = _image_like_data(4000, d, seed=2, non_iid=non_iid, n_clients=n)
    shards = jnp.asarray(_split(x, n))
    tag = "noniid" if non_iid else "iid"
    init = jnp.asarray(x[:: x.shape[0] // n_clusters][:n_clusters])

    for name, kw in ESTIMATORS + [("identity", {})]:
        spec = codec.build(name, k=k, d_block=d, **kw)

        @jax.jit
        def one_round(cents, key):
            d2 = ((shards[:, :, None, :] - cents[None, None]) ** 2).sum(-1)
            assign = jnp.argmin(d2, -1)  # (n, m)
            oh = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
            sums = jnp.einsum("nmc,nmd->ncd", oh, shards)
            cnts = oh.sum(1)[..., None]
            local = sums / jnp.maximum(cnts, 1.0)  # (n, c, d) local centroids
            est = mean_estimate(spec, key, local)  # chunks axis = clusters
            loss = (d2.min(-1)).mean()
            return est, loss

        def run():
            cents, loss = init, 0.0
            for t in range(iters):
                cents, loss = one_round(cents, jax.random.fold_in(jax.random.key(8), t))
            return loss

        sec, loss = timed(run, warmup=0, iters=1)
        rows(out, f"fig4/kmeans_{tag}/n{n}_k{k}/{name}", sec / iters * 1e6, f"{float(loss):.4f}")


def linreg(out, n=10, k=51, d=512, iters=30, lr=0.05, non_iid=False):
    rng = np.random.default_rng(3)
    w_star = rng.standard_normal(d).astype(np.float32) / np.sqrt(d)
    x, _ = _image_like_data(4000, d, seed=4, non_iid=non_iid, n_clients=n)
    y = x @ w_star + rng.standard_normal(x.shape[0]).astype(np.float32) * 0.01
    if non_iid:
        order = np.argsort(y)
        x, y = x[order], y[order]
    xs, ys = jnp.asarray(_split(x, n)), jnp.asarray(_split(y[:, None], n)[..., 0])
    tag = "noniid" if non_iid else "iid"

    for name, kw in ESTIMATORS + [("identity", {})]:
        spec = codec.build(name, k=k, d_block=d, **kw)

        @jax.jit
        def one_round(w, key):
            pred = jnp.einsum("nmd,d->nm", xs, w)
            grad_i = 2 * jnp.einsum("nmd,nm->nd", xs, pred - ys) / xs.shape[1]
            g = mean_estimate(spec, key, grad_i[:, None, :])[0]
            w = w - lr * g
            loss = ((pred - ys) ** 2).mean()
            return w, loss

        def run():
            w, loss = jnp.zeros(d), 0.0
            for t in range(iters):
                w, loss = one_round(w, jax.random.fold_in(jax.random.key(9), t))
            return loss

        sec, loss = timed(run, warmup=0, iters=1)
        rows(out, f"fig4/linreg_{tag}/n{n}_k{k}/{name}", sec / iters * 1e6, f"{float(loss):.5f}")


def run(out):
    power_iteration(out, non_iid=False)
    kmeans(out, non_iid=False)
    linreg(out, non_iid=False)
    # App. D.1 non-IID variants
    power_iteration(out, non_iid=True)
    linreg(out, non_iid=True)
