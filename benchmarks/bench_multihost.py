"""Multi-host hierarchical aggregation benchmark (docs/DESIGN.md §11).

    PYTHONPATH=src python -m benchmarks.bench_multihost --smoke

Spawns 2 REAL CPU processes via ``runtime.spawn_local`` (each decoding its
owned pod, exchanging per-pod records over the jax.distributed KV store)
and measures the hierarchical round driver under actual multi-process
execution: the base two-pod decode, the PR 4 ``overlap=`` double-buffered
chunk streaming, and the PR 5 ownership (all_to_all-routed) sub-decode
inside each pod. The ``dcn`` row reports the two-tier ledger in the
n·k > d regime the hierarchy exists for: per-round DCN bytes of the
hierarchical exchange vs the modelled flat all-payloads-to-one-server
uplink (``runtime.comms.cross_pod_traffic``), which the hierarchy must not
exceed.

Writes ``results/MULTIHOST_<mode>.json`` (benchmark artifact schema v1,
validated by ``tools/bench_artifacts.py validate`` before CI uploads it).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

N_PROCESSES = 2


def _base_spec(n_rounds: int) -> dict:
    return dict(
        task="drift",
        task_kw=dict(n_clients=8, d=128, rho=0.9, omega=0.05,
                     client_bias=0.5),
        stages=[("rand_proj_spatial", dict(k=16, d_block=64,
                                           transform="wavg"))],
        cohort=dict(n_clients=8),
        rounds=dict(n_rounds=n_rounds, hierarchy="hier", pods=2),
    )


def _nk_gt_d_spec(n_rounds: int) -> dict:
    """n·k = 1024 payload floats vs d = 128: the regime where shipping every
    payload to one server costs more DCN than exchanging pod estimates."""
    return dict(
        task="drift",
        task_kw=dict(n_clients=16, d=128, rho=0.9, omega=0.05,
                     client_bias=0.5),
        stages=[("rand_proj_spatial", dict(k=64, d_block=128,
                                           transform="avg"))],
        cohort=dict(n_clients=16),
        rounds=dict(n_rounds=n_rounds, hierarchy="hier", pods=2),
    )


def _spawn(spec: dict) -> dict:
    """Run the spec on N_PROCESSES real processes; return the slowest
    process's result (the round wall time the deployment would see)."""
    from repro.runtime import spawn_local
    from repro.runtime.workers import round_worker

    outs = spawn_local(round_worker, N_PROCESSES, args=(spec,))
    return max(outs, key=lambda o: o["wall_s"])


def _row(out: list[str], name: str, spec: dict, result: dict,
         derived: str = "") -> None:
    n_rounds = spec["rounds"]["n_rounds"]
    us = result["wall_s"] / n_rounds * 1e6
    extra = (f"bytes_per_round={int(result['total_bytes']) // n_rounds};"
             f"dcn_per_round={int(result['total_dcn_bytes']) // n_rounds}")
    out.append(f"{name},{us:.1f},{derived + extra}")


def run(out: list[str], n_rounds: int = 3) -> None:
    import numpy as np

    from repro.fl import Cohort
    from repro.runtime import PodPlan, cross_pod_traffic
    from repro.runtime.workers import build_pipeline

    base = _base_spec(n_rounds)
    _row(out, f"multihost/p{N_PROCESSES}_pods2/base", base, _spawn(base))

    overlap = dict(base, rounds=dict(base["rounds"], overlap=True))
    _row(out, f"multihost/p{N_PROCESSES}_pods2/overlap", overlap,
         _spawn(overlap))

    owner = dict(base, rounds=dict(base["rounds"], ownership=True,
                                   n_owners=2))
    res_owner = _spawn(owner)
    _row(out, f"multihost/p{N_PROCESSES}_pods2/ownership", owner, res_owner,
         derived=f"intra_pod_per_round="
                 f"{int(res_owner['total_intra_pod_bytes']) // n_rounds};")

    # two-tier ledger in the n*k > d regime: real per-round DCN bytes vs the
    # modelled flat uplink — the acceptance bound (hier <= flat)
    big = _nk_gt_d_spec(n_rounds)
    res_big = _spawn(big)
    pipe = build_pipeline(big["stages"])
    n = big["cohort"]["n_clients"]
    info = cross_pod_traffic(pipe, Cohort(n_clients=n), np.arange(n),
                             PodPlan(n_clients=n, n_pods=2), n_chunks=1)
    dcn_round = int(res_big["total_dcn_bytes"]) // n_rounds
    if dcn_round > info["dcn_bytes_flat"]:
        raise SystemExit(
            f"multihost: DCN regression: hier {dcn_round} B/round > flat "
            f"{info['dcn_bytes_flat']} B/round in the n*k > d regime"
        )
    _row(out, f"multihost/p{N_PROCESSES}_pods2/dcn_nk_gt_d", big, res_big,
         derived=f"dcn_flat_model={info['dcn_bytes_flat']};"
                 f"dcn_hier_model={info['dcn_bytes_hier']};")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced rounds; writes results/MULTIHOST_smoke.json")
    ap.add_argument("--rounds", type=int, default=0,
                    help="override round count (default 3 smoke / 10 full)")
    args = ap.parse_args()
    mode = "smoke" if args.smoke else "full"
    n_rounds = args.rounds or (3 if args.smoke else 10)

    out: list[str] = ["name,us_per_call,derived"]
    t0 = time.time()
    run(out, n_rounds=n_rounds)
    secs = time.time() - t0
    print("\n".join(out))

    from benchmarks.run import run_metadata

    records = []
    for line in out[1:]:
        name, us, derived = line.split(",", 2)
        records.append({"name": name, "us_per_call": float(us),
                        "derived": derived})
    meta = run_metadata(mode)
    meta["n_processes"] = N_PROCESSES
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"MULTIHOST_{mode}.json")
    with open(path, "w") as f:
        json.dump({"schema_version": 1, "mode": mode, "run": meta,
                   "total_s": round(secs, 1), "rows": records}, f, indent=1)
    print(f"# total {secs:.1f}s, {len(records)} rows -> {path}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
