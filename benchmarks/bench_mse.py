"""Paper Fig. 2 (identical vectors), Thm 4.4 check (orthogonal vectors) and
Fig. 3/6 (varying degrees of correlation R)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import codec, correlation

from .common import base_vector_clients, mse_over_trials, rows


def fig2_identical(out, trials=300):
    """Identical client vectors: Rand-Proj-Spatial(Max) ~ (d/nk - 1)||x||^2."""
    d = 1024
    rng = np.random.default_rng(0)
    for n, k in [(10, 25), (10, 51), (20, 25), (50, 10)]:
        x = rng.standard_normal(d).astype(np.float32)
        x /= np.linalg.norm(x)
        xs = jnp.asarray(np.tile(x, (n, 1))[:, None, :])
        res = {}
        for name, tf in [("rand_k", "one"), ("rand_k_spatial", "max"),
                         ("rand_proj_spatial", "max")]:
            spec = codec.build(name, k=k, d_block=d, transform=tf)
            mse, sec = mse_over_trials(spec, xs, trials)
            res[name] = mse
            rows(out, f"fig2/identical/n{n}_k{k}/{name}", sec * 1e6, f"{mse:.4f}")
        theory = d / (n * k) - 1
        rows(out, f"fig2/identical/n{n}_k{k}/theory_thm4.3", 0,
             f"{max(theory, 0):.4f}")


def thm44_orthogonal(out, trials=400):
    d, n, k = 1024, 8, 16
    rng = np.random.default_rng(1)
    q, _ = np.linalg.qr(rng.standard_normal((d, n)))
    xs = jnp.asarray((q.T / np.linalg.norm(q.T, axis=1, keepdims=True))[:, None, :],
                     jnp.float32)
    for name, tf in [("rand_k", "one"), ("rand_proj_spatial", "one")]:
        spec = codec.build(name, k=k, d_block=d, transform=tf)
        mse, sec = mse_over_trials(spec, xs, trials)
        rows(out, f"thm4.4/orthogonal/n{n}_k{k}/{name}", sec * 1e6, f"{mse:.4f}")
    # Eq. 1 with unit-norm clients: (1/n^2)(d/k - 1) * n
    rows(out, f"thm4.4/orthogonal/n{n}_k{k}/theory_eq1", 0, f"{(d/k-1)/n:.4f}")


def fig3_correlation(out, trials=300):
    """Varying R (paper's base-vector group construction), n=21, d=1024.

    NOTE on noise: with one-hot client vectors, per-trial Rand-k MSE is
    heavy-tailed (collision-pattern dependent), so its empirical mean
    converges slowly; Eq. 1 is EXACT for Rand-k independent of the data, so
    the theory row is the right comparison line. Rand-Proj-Spatial's
    per-trial variance is tiny (SRHT mixes coordinates), making its
    empirical mean reliable at these trial counts.
    """
    d, n, k = 1024, 21, 32
    eq1 = (1 / n**2) * (d / k - 1) * n  # unit-norm clients
    for sizes, label in [([6, 5, 4, 3, 2, 1], "R3.9"), ([12, 6, 3], "R8"),
                         ([17, 4], "R13.1"), ([21], "R20")]:
        assign = np.concatenate([[g] * c for g, c in enumerate(sizes)])
        xs = jnp.asarray(np.eye(d)[assign][:, None, :], jnp.float32)
        r = float(correlation.r_exact(xs))
        rows(out, f"fig3/{label}/n{n}_k{k}/rand_k_theory_eq1", 0, f"{eq1:.4f}")
        for name, tf in [("rand_k_spatial", "opt"), ("rand_proj_spatial", "opt"),
                         ("sparse_proj", "opt")]:
            spec = codec.build(name, k=k, d_block=d, transform=tf, r_value=r)
            mse, sec = mse_over_trials(spec, xs, trials)
            rows(out, f"fig3/{label}/n{n}_k{k}/{name}", sec * 1e6,
                 f"{mse:.4f};vs_eq1={mse/eq1:.3f}")


def practical_avg_and_est(out, trials=200):
    """Rand-Proj-Spatial(Avg) (paper practical) vs (Est) (ours, online R-hat)."""
    d, n, k = 1024, 21, 32
    xs, r = base_vector_clients(n, d, 3, seed=5)
    for name, kw, label in [
        ("rand_k", {}, "rand_k"),
        ("rand_k_spatial", dict(transform="avg"), "rand_k_spatial_avg"),
        ("rand_proj_spatial", dict(transform="avg"), "rand_proj_spatial_avg"),
        ("rand_proj_spatial", dict(transform="opt", r_mode="est"), "rand_proj_spatial_est"),
        ("rand_proj_spatial", dict(transform="opt", r_value=r), "rand_proj_spatial_oracle"),
        ("sparse_proj", dict(transform="avg"), "sparse_proj_avg"),
        ("sparse_proj", dict(transform="opt", r_mode="est"), "sparse_proj_est"),
    ]:
        spec = codec.build(name, k=k, d_block=d, **kw)
        mse, sec = mse_over_trials(spec, xs, trials)
        rows(out, f"practical/R{r:.1f}/n{n}_k{k}/{label}", sec * 1e6, f"{mse:.4f}")


def run(out):
    fig2_identical(out)
    thm44_orthogonal(out)
    fig3_correlation(out)
    practical_avg_and_est(out)
