"""Roofline analysis from the compiled dry-run artifacts (docs/EXPERIMENTS.md §Roofline).

Terms (per device; TPU v5e constants from launch/mesh.py):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes / HBM_bw
    collective = collective_wire_bytes / ICI_link_bw

HLO_FLOPs / bytes / collective bytes come from the two-point unrolled
calibration (launch/dryrun.py --calibrate): XLA's cost analysis counts
while-loop bodies once, so scanned-layer models are otherwise undercounted;
the calibration compiles nb in {1,2} with zero while loops and extrapolates
f(nb) = a + b*nb to full depth (exact for block-homogeneous models).

MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*tokens (serve); the ratio
MODEL_FLOPS / (HLO_FLOPs * chips) flags redundant/replicated compute.
roofline_fraction = time-at-peak-for-useful-flops / dominant-term-time:
the fraction of the roofline the step achieves if it runs exactly at the
bound of its dominant term.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get_config(arch)
    n_active = cfg.n_params_active()
    info = SHAPES[shape_name]
    if info["kind"] == "train":
        return 6.0 * n_active * info["batch"] * info["seq"]
    if info["kind"] == "prefill":
        return 2.0 * n_active * info["batch"] * info["seq"]
    return 2.0 * n_active * info["batch"]  # decode: one token per request


def load_cell(arch, shape, mesh="pod16x16", dme="off", tag=""):
    path = os.path.join(RESULTS, f"{arch}__{shape}__{mesh}__{dme}{('_'+tag) if tag else ''}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def analyze_cell(arch, shape, mesh="pod16x16", dme="off", tag_calib="auto"):
    base = load_cell(arch, shape, mesh, dme)
    if tag_calib == "auto":  # prefer the optimized-sharding recalibration
        calib = load_cell(arch, shape, mesh, dme, "calib_opt") or load_cell(
            arch, shape, mesh, dme, "calib"
        )
    else:
        calib = load_cell(arch, shape, mesh, dme, tag_calib)
    if base is None:
        return None
    if base.get("status") == "skipped":
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "skipped",
                "reason": base.get("reason", "")}
    if base.get("status") != "ok":
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "reason": base.get("error", "")[:200]}
    chips = base["n_devices"]
    if calib and calib.get("status") == "ok":
        flops = calib["flops_full"]
        mem_bytes = calib["bytes_full"]
        wire = calib["wire_bytes_full"]
        src = "calibrated"
    else:
        flops = base["cost"].get("flops", 0.0)
        mem_bytes = base["cost"].get("bytes accessed", 0.0)
        wire = base["collectives"]["totals"]["wire_bytes"]
        src = "raw(while-once)"
    t_c = flops / PEAK_FLOPS_BF16
    t_m = mem_bytes / HBM_BW
    t_x = wire / ICI_BW
    # fusion-aware analytic memory model (see memory_model.py): the HLO
    # 'bytes accessed' is a per-op unfused UPPER bound (~5-10x real traffic);
    # bottleneck classification and the reported fraction use the model.
    from .memory_model import analytic_memory_bytes

    pod = 2 if "2x" in mesh else 1
    t_m_model = analytic_memory_bytes(arch, shape, pod=pod)["total"] / HBM_BW
    terms = {"compute": t_c, "memory": t_m_model, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    useful_t = mf / chips / PEAK_FLOPS_BF16
    frac = useful_t / max(max(terms.values()), 1e-30)
    frac_hlo = useful_t / max(t_c, t_m, t_x)
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "dme": dme, "status": "ok",
        "chips": chips, "source": src,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_memory_model_s": t_m_model,
        "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": flops * chips,
        "useful_ratio": mf / max(flops * chips, 1e-30),
        "roofline_fraction": frac,
        "roofline_fraction_hlo": frac_hlo,
        "memory_analysis": base.get("memory", {}),
    }


def full_table(mesh="pod16x16", dme="off", tag_calib="auto"):
    out = []
    for arch in configs.ARCHS:
        for shape in SHAPES:
            rec = analyze_cell(arch, shape, mesh, dme, tag_calib)
            if rec is not None:
                out.append(rec)
    return out


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | chips | compute s | mem s (HLO) | mem s (model) | "
           "collective s | dominant | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | skipped | - | "
                f"{r.get('reason','')[:60]} |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_memory_model_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def run(out):
    rows = full_table()
    ok = [r for r in rows if r["status"] == "ok"]
    for r in ok:
        out.append(
            f"roofline/{r['arch']}/{r['shape']},0,"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};useful={r['useful_ratio']:.2f}"
        )
    md = "## After (optimized sharding)\n\n" + render_markdown(rows)
    before = full_table(tag_calib="calib")
    md += "\n\n## Before (baseline sharding)\n\n" + render_markdown(before)
    path = os.path.join(os.path.dirname(__file__), "..", "results", "roofline.md")
    with open(path, "w") as f:
        f.write(md + "\n")
    out.append(f"roofline/table_cells,0,{len(ok)}ok/{len(rows)}total->results/roofline.md")


if __name__ == "__main__":
    rows = full_table()
    print(render_markdown(rows))
