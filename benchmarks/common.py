"""Shared benchmark utilities: timing + the paper's simulation setups."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import correlation, mean_estimate


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters, out


def timed_with_compile(fn, *args, iters=3, obs_name=None):
    """(first-call sec, steady-state sec/call, out) for a fresh-jitted fn.

    The first call traces + compiles; reporting it as its own column keeps
    compile time from polluting steady-state walltime rows (and makes
    compile-time regressions visible instead of folded into an average).
    ``obs_name`` additionally records the pair as ``bench/<obs_name>``
    compile/steady gauges in the repro.obs registry (when enabled)."""
    t0 = time.time()
    out = jax.block_until_ready(fn(*args))
    compile_sec = time.time() - t0
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    steady_sec = (time.time() - t0) / iters
    if obs_name is not None:
        from repro import obs

        obs.record_compile("bench", obs_name, compile_sec, steady_sec)
    return compile_sec, steady_sec, out


def mse_over_trials(spec, xs, trials: int, seed: int = 0):
    # ``spec``: a codec Pipeline or sparsifier config (mean_estimate normalises)
    """Mean squared error E||x_hat - x_bar||^2 over `trials` rounds, timed."""
    xbar = jnp.mean(xs, axis=0)

    @jax.jit
    def one(key):
        return correlation.mse(mean_estimate(spec, key, xs), xbar)

    keys = jax.random.split(jax.random.key(seed), trials)
    secs, mses = timed(lambda: jax.lax.map(one, keys))
    return float(jnp.mean(mses)), secs / trials


def base_vector_clients(n: int, d: int, n_groups: int, seed: int = 0):
    """Paper §4.3 setup: clients hold canonical basis vectors; #clients per
    group controls R. Returns (xs (n,1,d), R)."""
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, n_groups, n)
    xs = np.eye(d)[assign][:, None, :].astype(np.float32)
    xs_j = jnp.asarray(xs)
    return xs_j, float(correlation.r_exact(xs_j))


def rows(out_list, name, us, derived):
    out_list.append(f"{name},{us:.1f},{derived}")
