"""First-order fusion-aware HBM-traffic model (per device, per step).

XLA's cost_analysis 'bytes accessed' charges every HLO op's operands and
results as if nothing fuses — a per-op UPPER bound that lands ~5-10x above
real TPU HBM traffic for transformer steps (measured arithmetic intensity
~12 flop/byte, vs >100 for fused bf16 stacks). For bottleneck
classification and the roofline fraction we therefore model traffic at
fusion granularity: each MAJOR tensor (weights, layer activations,
attention scores, MoE buffers, SSD chunk tensors, KV cache) is charged once
per producing/consuming fusion, with a x3 fwd/remat/bwd multiplier for
training. Both numbers are reported side by side in docs/EXPERIMENTS.md.

Key term this model exposes (and the flash-attention kernel removes): the
materialised attention score tensor, tokens*S*heads_local*4B per layer —
XLA cannot keep it in VMEM across the matmul->softmax->matmul boundary.
"""
from __future__ import annotations

from repro import configs
from repro.launch.specs import SHAPES

BF16 = 2
F32 = 4


def _shards(n, ways):
    return n // ways if ways and n % ways == 0 else n


def analytic_memory_bytes(arch: str, shape_name: str, *, data=16, model=16, pod=1,
                          flash_attention=False, kv_block=None) -> dict:
    cfg = configs.get_config(arch)
    info = SHAPES[shape_name]
    kind = info["kind"]
    chips = data * model * pod
    dp = data * pod
    b, s = info["batch"], info["seq"]
    ms = model
    kv_block = kv_block or cfg.attn_kv_block

    if kind == "train":
        tokens = b * s // dp
        train_mult = 3.0  # fwd + remat-fwd + bwd passes over activations/weights
    elif kind == "prefill":
        tokens = max(b // dp, 1) * s
        train_mult = 1.0
    else:
        tokens = max(b // dp, 1)
        train_mult = 1.0

    d = cfg.d_model
    weights = 0.0   # bytes of weights streamed per pass (bf16, TP-sharded)
    acts = 0.0      # major activation tensors, read+write once each
    scores = 0.0    # attention score matrices (the flash-kernel target)
    cache_rw = 0.0  # decode KV-cache reads

    for spec in cfg.layers:
        if spec.kind == "attn":
            h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            wq = d * h * dh + 2 * d * kv * dh + h * dh * d
            weights += BF16 * wq / ms
            heads_local = _shards(h * dh, ms) // dh or 1
            # x, norm, q/k/v/o projections in+out
            acts += tokens * BF16 * (6 * d + 2 * (h * dh + 2 * kv * dh) / ms)
            if kind == "decode":
                s_ctx = min(s, spec.window) if spec.window else s
                # read the whole (sharded) cache to score one token
                cache_rw += (b // dp if b >= dp else b) * s_ctx * kv * dh * 2 * BF16 / (
                    1 if b >= dp else chips // 1)
                scores += tokens * s_ctx * heads_local * F32 * 2
            else:
                s_ctx = min(s, spec.window) if spec.window else s
                if flash_attention:
                    # VMEM-resident tiles: KV re-read once per query tile
                    n_qtiles = max(tokens // kv_block, 1)
                    acts += n_qtiles * s_ctx * kv * dh * 2 * BF16
                else:
                    # scores hit HBM at the matmul->softmax->matmul boundary
                    scores += tokens * s_ctx * heads_local * F32 * 2
        else:
            di, nh, hd = cfg.mamba_d_inner, cfg.mamba_heads, cfg.mamba_headdim
            n_state, q = cfg.d_state, cfg.mamba_chunk
            p_in = 2 * di + 2 * cfg.mamba_ngroups * n_state + nh
            weights += BF16 * (d * p_in + di * d) / (ms if p_in % ms == 0 else 1)
            acts += tokens * BF16 * (6 * d + 2 * p_in)
            if kind != "decode":
                # SSD decay/score chunk tensors (b, nc, h, q, q) hit HBM
                scores += tokens * q * nh * F32 * 2
                acts += tokens * (nh * n_state) * F32  # states
            else:
                cache_rw += nh * n_state * hd * F32 * 2 * max(b // dp, 1)

        if spec.ffn == "dense":
            weights += BF16 * 3 * d * cfg.d_ff / ms
            acts += tokens * BF16 * (4 * d + 3 * cfg.d_ff / ms)
        elif spec.ffn == "moe":
            e, fe, topk = cfg.n_experts, cfg.d_ff_expert, cfg.top_k_experts
            weights += BF16 * 3 * e * d * fe / ms
            # dispatch buffers (E, C, d) in + out and expert hiddens
            cap_tokens = tokens * topk * cfg.capacity_factor
            acts += cap_tokens * BF16 * (4 * d + 3 * fe / ms)
            if cfg.n_shared_experts:
                fs = cfg.n_shared_experts * fe
                weights += BF16 * 3 * d * fs / ms
                acts += tokens * BF16 * 3 * fs / ms

    # embeddings + lm head
    weights += BF16 * cfg.vocab_padded * d / ms * (1 if kind != "train" else 2)
    acts += tokens * F32 * cfg.vocab_padded / ms  # logits
    total = train_mult * (weights + acts + scores) + cache_rw
    if kind == "train":
        # optimizer: read+write p (f32), m, v + grad read on the FSDP shard
        n = cfg.n_params()
        total += 8 * F32 * n / chips
    return {
        "total": total,
        "weights": train_mult * weights,
        "acts": train_mult * acts,
        "scores": train_mult * scores,
        "cache": cache_rw,
    }
