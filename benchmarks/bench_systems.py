"""Paper Fig. 5 (encode/decode wall-clock) + Fig. 7 (rank(S)) + kernel
micro-benchmarks (FWHT pallas-vs-oracle) + framework-scale chunked DME +
the sharded-server-decode (chunk ownership) intra-pod traffic model."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core import beta as beta_lib
from repro.core.estimators import base as est_base
from repro.dist import collectives
from repro.dist.sharding import chunk_ownership
from repro.kernels import ops as kops

from .common import rows, timed, timed_with_compile


def walltime(out, n=10, k=102, d=1024):
    """Fig. 5: per-client encode time and server decode time."""
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n, 1, d)), jnp.float32)
    key = jax.random.key(0)
    for name, kw in [
        ("rand_k", {}), ("rand_k_spatial", {"transform": "avg"}),
        ("rand_proj_spatial", {"transform": "avg"}),
        ("top_k", {}), ("wangni", {}), ("induced", {}),
    ]:
        spec = codec.build(name, k=k, d_block=d, **kw)
        enc = jax.jit(lambda key, x: est_base.encode(spec, key, 0, x))
        sec_e, payload0 = timed(enc, key, xs[0])
        payloads = jax.jit(lambda key, xs: est_base.encode_all(spec, key, xs))(key, xs)
        dec = jax.jit(lambda key, p: est_base.decode(spec, key, p, n))
        sec_d, _ = timed(dec, key, payloads)
        rows(out, f"fig5/encode/n{n}_k{k}/{name}", sec_e * 1e6, "per-client")
        rows(out, f"fig5/decode/n{n}_k{k}/{name}", sec_d * 1e6, "server")


def rank_s(out, trials=200):
    """Fig. 7: rank(S) == nk w.h.p. for SRHT."""
    for d, nk_pairs in [(256, [(8, 16)]), (1024, [(8, 64), (16, 32)])]:
        for n, k in nk_pairs:
            bank = beta_lib.srht_eig_bank(n, k, d, trials=trials, seed=11)
            frac = ((np.asarray(bank) > 1e-4).sum(1) == n * k).mean()
            rows(out, f"fig7/rank_full_frac/d{d}_nk{n*k}", 0, f"{frac:.4f}")


def fwht_kernel(out):
    """Pallas (interpret) vs jnp-oracle FWHT; correctness is tested in
    tests/test_kernels.py — here we record throughput shape-sweep."""
    rng = np.random.default_rng(1)
    for d in (512, 1024, 4096):
        x = jnp.asarray(rng.standard_normal((256, d)), jnp.float32)
        sec_ref, _ = timed(jax.jit(lambda t: kops.fwht(t, use_pallas="never")), x)
        rows(out, f"kernel/fwht_oracle/d{d}", sec_ref * 1e6,
             f"{256 * d * np.log2(d) / sec_ref / 1e9:.2f}GOPs")
        sec_pl, _ = timed(jax.jit(lambda t: kops.fwht(t, use_pallas="force")), x)
        rows(out, f"kernel/fwht_pallas_interp/d{d}", sec_pl * 1e6, "interpret-mode")


def chunked_scale(out):
    """Framework-scale: DME over a 4M-dim gradient, shared-randomness Gram
    decode (one eigh for all chunks) vs paper-faithful per-chunk decode."""
    n, k, d = 8, 64, 1024
    d_flat = 1 << 22  # 4.2M
    c = d_flat // d
    rng = np.random.default_rng(2)
    base = rng.standard_normal(d_flat).astype(np.float32)
    xs = jnp.asarray(
        np.stack([base + 0.1 * rng.standard_normal(d_flat) for _ in range(n)])
    ).reshape(n, c, d)
    key = jax.random.key(3)
    for shared, label in [(True, "shared_gram"), (False, "per_chunk_paper")]:
        spec = codec.build("rand_proj_spatial", k=k, d_block=d,
                             transform="avg", shared_randomness=shared)
        if not shared:
            xs_small = xs[:, :32]  # paper-faithful path is O(C) eighs; sample
            fn = jax.jit(lambda key, t: est_base.decode(
                spec, key, est_base.encode_all(spec, key, t), n))
            sec, _ = timed(fn, key, xs_small)
            sec = sec * (c / 32)
        else:
            fn = jax.jit(lambda key, t: est_base.decode(
                spec, key, est_base.encode_all(spec, key, t), n))
            sec, _ = timed(fn, key, xs)
        rows(out, f"scale/dme_4M_roundtrip/{label}", sec * 1e6,
             f"{d_flat / sec / 1e6:.1f} Mcoord/s")


def ownership(out, n=32, k=64, d=512, n_chunks=64):
    """Sharded server decode (docs/DESIGN.md §10): modelled intra-pod
    receive traffic, all-gather vs chunk-ownership routing, across shard
    counts — the ``intra_pod_bytes`` columns that land in BENCH_*.json —
    plus the measured owner decode walltime (parity with the monolithic
    decode is tested; here we record that the partition WINS wall-clock).

    The measured rows time the per-owner CRITICAL PATH: owners decode their
    equal-width chunk slices in parallel in deployment, so the honest
    distributed walltime is one owner's slice decode (the widest, owner 0)
    at its global chunk offset — not the sum over owners. Compile (first
    call: trace + lowering) is reported as its own ``compile_us`` column
    rather than folded into the steady-state number.

    The traffic-reduction regime is (n - n/s) * payload_bytes > C * d * 4
    (remote payloads outweigh the decoded vector); the assertion guards the
    model the EXPERIMENTS.md section documents. For the fused
    rand_proj_spatial decode the per-owner walltime must beat monolithic at
    EVERY shard count (the kernel fast path's acceptance criterion).
    """
    pipe = codec.as_pipeline(codec.RandK(k=k, d_block=d))
    for n_shards in (2, 4, 8, 16):
        plan = chunk_ownership(n_chunks, n_shards)
        t = collectives.intra_pod_traffic(pipe, n, n_chunks, n_shards,
                                          plan=plan)
        ag, own = t["intra_pod_bytes_allgather"], t["intra_pod_bytes_ownership"]
        assert own < ag, (own, ag)  # the acceptance regime for this config
        rows(out, f"ownership/intra_pod/n{n}_k{k}_d{d}_C{n_chunks}/s{n_shards}",
             0, f"allgather={ag};ownership={own};reduction={ag / own:.2f}x")

    # measured: per-owner critical-path decode vs the monolithic decode
    rng = np.random.default_rng(7)
    xs = jnp.asarray(rng.standard_normal((n, n_chunks, d)), jnp.float32)
    key = jax.random.key(7)
    for est_name, est_pipe in [
        ("rand_k", pipe),
        ("rand_proj_spatial", codec.as_pipeline(
            codec.RandProjSpatial(k=k, d_block=d, transform="avg"))),
    ]:
        payloads, _ = est_pipe.encode_all(key, xs)
        comp_m, sec_mono, _ = timed_with_compile(
            jax.jit(lambda kk: est_pipe.decode_payload(kk, payloads, n)), key,
            obs_name=f"decode_monolithic/{est_name}")
        rows(out,
             f"ownership/decode_monolithic/n{n}_k{k}_d{d}_C{n_chunks}/{est_name}",
             sec_mono * 1e6, f"server;compile_us={comp_m * 1e6:.0f}")
        for n_shards in (2, 4, 8, 16):
            plan = chunk_ownership(n_chunks, n_shards)
            lo, hi = plan.slice_for(0)
            sliced = jax.tree.map(lambda leaf: leaf[:, lo:hi], payloads)
            comp_o, sec_own, _ = timed_with_compile(
                jax.jit(lambda kk: est_pipe.decode_payload(
                    kk, sliced, n, chunk_offset=lo)), key,
                obs_name=f"decode_sharded/{est_name}/s{n_shards}")
            if est_name == "rand_proj_spatial":
                assert sec_own < sec_mono, (n_shards, sec_own, sec_mono)
            rows(out,
                 f"ownership/decode_sharded/n{n}_k{k}_d{d}_C{n_chunks}"
                 f"/{est_name}/s{n_shards}",
                 sec_own * 1e6,
                 f"{sec_mono / sec_own:.2f}x_vs_monolithic;"
                 f"per_owner_critical_path;compile_us={comp_o * 1e6:.0f}")


def fused_kernels(out, n=8, k=64, d=1024, n_chunks=4):
    """Fused (matrix-free CG, kernels/srht_fused.py) vs unfused (Gram eigh)
    rand_proj_spatial decode walltime — the rows behind the CI
    ``KERNELS_smoke.json`` artifact; the bench-smoke job FAILS if the fused
    decode is not faster than the unfused path on the smoke grid."""
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.standard_normal((n, n_chunks, d)), jnp.float32)
    key = jax.random.key(9)
    for label, kw in [("srht", {}), ("subsample", {"projection": "subsample"})]:
        for variant, method in [("fused", "fused"), ("unfused", "gram")]:
            sp = codec.RandProjSpatial(k=k, d_block=d, transform="avg",
                                       decode_method=method, **kw)
            est_pipe = codec.as_pipeline(sp)
            payloads, _ = est_pipe.encode_all(key, xs)
            comp, sec, _ = timed_with_compile(
                jax.jit(lambda kk: est_pipe.decode_payload(kk, payloads, n)),
                key, obs_name=f"decode/{label}/{variant}")
            rows(out,
                 f"kernel_fused/decode/n{n}_k{k}_d{d}_C{n_chunks}"
                 f"/{label}/{variant}",
                 sec * 1e6, f"compile_us={comp * 1e6:.0f}")


def sparseproj_encode(out, k=64, d=1024, n_chunks=4, s=32.0):
    """Cheap-encode frontier (EXPERIMENTS.md): very-sparse projection vs the
    SRHT per-client encode at EQUAL budget k — wall-clock AND the declared
    per-chunk encode flops; the rows behind the CI ``SPARSEPROJ_smoke.json``
    artifact. ``tools/bench_artifacts.py extract sparseproj`` FAILS the
    bench-smoke job unless the sparse_proj row exists and beats the srht row
    on BOTH columns (O(k d / s) gather vs O(d log d) FWHT — at these shapes
    the draw + gather must win outright, not just asymptotically)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((n_chunks, d)), jnp.float32)
    key = jax.random.key(11)
    for label, sp in [
        ("srht", codec.RandProjSpatial(k=k, d_block=d, transform="avg")),
        ("sparse_proj", codec.SparseProj(k=k, d_block=d, s=s,
                                         transform="avg")),
    ]:
        pipe = codec.as_pipeline(sp)
        enc = jax.jit(lambda kk, p=pipe: p.encode_payload(kk, 0, x))
        comp, sec, _ = timed_with_compile(
            enc, key, obs_name=f"sparseproj_encode/{label}")
        rows(out, f"sparseproj/encode/k{k}_d{d}_C{n_chunks}/{label}",
             sec * 1e6,
             f"flops_per_chunk={sp.encode_flops_per_chunk()};"
             f"compile_us={comp * 1e6:.0f}")


def quant(out, n=8, k=256, d=1024, n_chunks=4, trials=8):
    """Correlated-quantization + entropy-coding rows behind the CI
    ``QUANT_smoke.json`` artifact (``tools/bench_artifacts.py extract
    quant``).

    ``quant/mse`` rows measure the pure QUANTIZATION error: the same round
    keys drive the quantized and the unquantized pipeline (identical
    sparsifier draws), so ``mean |est_q - est_f|^2`` isolates the rounding
    noise from the sparsifier noise that otherwise dominates total MSE. The
    gated setting is the identity sparsifier — full-vector quantization DME,
    where every client quantizes the SAME coordinate at the same dither
    position, which is exactly where Suresh et al.'s anti-correlated offsets
    cancel in the cohort mean. (Composed with per-client supports — rand_k
    permutations, top-k selections — clients' dither positions never meet at
    an output coordinate, so CorrelatedQuant matches Int8Quant's independent
    stochastic rounding there instead of beating it; it never does worse.)
    The gate requires every ``/correlated`` row to strictly beat its
    ``/int8`` sibling at IDENTICAL wire bytes.

    ``quant/coded`` rows charge each payload stack at its EXACT entropy-coded
    stream length (codec.coded_payload_nbytes) next to the raw schema size;
    the gate requires coded <= raw for every row (float arrays ride raw and
    headerless, so a float-only payload is charged exactly its raw size).
    """
    rng = np.random.default_rng(13)
    base = rng.standard_normal((n_chunks, d)).astype(np.float32)
    xs = jnp.asarray(
        np.stack([base + 0.25 * rng.standard_normal((n_chunks, d))
                  for _ in range(n)]), jnp.float32)
    raw_pipe = codec.build("identity", d_block=d)
    for qname in ("int8", "correlated"):
        pipe = codec.build("identity", d_block=d, payload_dtype=qname)
        err = 0.0
        for t in range(2 * trials):
            kk = jax.random.key(100 + t)
            p_q, _ = pipe.encode_all(kk, xs)
            p_f, _ = raw_pipe.encode_all(kk, xs)
            est_q = pipe.decode_payload(kk, p_q, n)
            est_f = raw_pipe.decode_payload(kk, p_f, n)
            err += float(jnp.mean((est_q - est_f) ** 2))
        rows(out, f"quant/mse/n{n}_d{d}_C{n_chunks}/identity/{qname}",
             0, f"mean_mse={err / (2 * trials):.9f};paired_keys={2 * trials}")
    for sp_name in ("rand_k", "top_k"):
        for qname in ("none", "bfloat16", "int8", "correlated"):
            dtype = "float32" if qname == "none" else qname
            pipe_nc = codec.build(sp_name, k=k, d_block=d, payload_dtype=dtype)
            pipe = codec.build(sp_name, k=k, d_block=d, payload_dtype=dtype,
                               entropy_code=True)
            kk = jax.random.key(200)
            payloads, _ = pipe.encode_all(kk, xs)
            coded = codec.coded_payload_nbytes(pipe, payloads)
            raw = pipe_nc.payload_nbytes(n_chunks) * n
            rows(out,
                 f"quant/coded/n{n}_k{k}_d{d}_C{n_chunks}/{sp_name}/{qname}",
                 0, f"coded_bytes={coded};raw_bytes={raw};"
                    f"ratio={coded / raw:.3f}")


def run(out):
    walltime(out)
    rank_s(out)
    fwht_kernel(out)
    chunked_scale(out)
    ownership(out)
    fused_kernels(out)
    sparseproj_encode(out)
    quant(out)
