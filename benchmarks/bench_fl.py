"""repro.fl benchmark: MSE-vs-round and bytes-to-target-accuracy curves per
task x estimator (the paper's Fig. 4 measured at workload level, plus the
temporal-decoding comparison the paper's related work motivates).

Rows:
    fl/<task>/<estimator>[.temporal]     us_per_round    final=<metric>;
        mean_mse=<...>;bytes=<total>;bytes_to_target=<...|never>

``heterogeneous`` runs a mixed-budget cohort on BOTH the local and gspmd
backends and asserts the per-client byte ledgers sum to the same totals —
the payload's self-described budget metadata is what makes the gspmd decode
possible at all (codec Pipeline API).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

from .common import rows

ESTIMATORS = [
    ("rand_k", dict(), False),
    ("rand_k_spatial", dict(transform="avg"), False),
    ("rand_proj_spatial", dict(transform="avg"), False),
    ("rand_proj_spatial", dict(transform="wavg"), False),
    ("rand_proj_spatial", dict(transform="avg"), True),  # temporal decode
    ("sparse_proj", dict(transform="avg"), False),       # cheap-encode row
    # quantized + entropy-coded wire rows: the coded_bytes ledger and the
    # bytes-to-target-at-coded-bytes column (docs/EXPERIMENTS.md)
    ("rand_k", dict(payload_dtype="int8", entropy_code=True), False),
    ("rand_k", dict(payload_dtype="correlated", entropy_code=True), False),
]


def _tag(est, kw, temporal):
    """Row label: estimator.transform plus quantizer / coded markers, so the
    quantized variants never collide with the float32 row of the same name."""
    tag = f"{est}.{kw.get('transform', 'one')}"
    if kw.get("payload_dtype", "float32") != "float32":
        tag += f".{kw['payload_dtype']}"
    if kw.get("entropy_code"):
        tag += ".coded"
    return tag + (".temporal" if temporal else "")

# (task factory kwargs, d_block, k, rounds, bytes-to-target threshold)
SETUPS = {
    "dme": (dict(n_clients=8, d=256, rho=0.9), 256, 26, 40, None),
    "drift": (dict(n_clients=8, d=256, rho=0.95, omega=0.03), 256, 26, 40, None),
    "power_iteration": (dict(n_clients=10, d=1024, samples=4000), 1024, 102, 15, 0.5),
    "linear_regression": (dict(n_clients=10, d=512, samples=4000), 512, 51, 30, 0.05),
    "logistic_regression": (
        dict(n_clients=10, feat=64, samples=4000, scheme="dirichlet"), 1024, 102, 30, 0.5
    ),
}


def run_setup(out, name, task_kw, d_block, k, n_rounds, target, cohort=None):
    task = get_task(name, **task_kw)
    cohort = cohort or Cohort(n_clients=task.n_clients)
    for est, kw, temporal in ESTIMATORS:
        pipe = codec.build(est, k=k, d_block=d_block, **kw)
        cfg = RoundConfig(n_rounds=n_rounds, temporal=temporal)
        t0 = time.time()
        state, hist = run_rounds(task, pipe, cohort, cfg)
        us_round = (time.time() - t0) / n_rounds * 1e6
        final = "nan" if task.metric is None else f"{hist.metric[-1]:.5f}"
        btt, btt_coded = "n/a", "n/a"
        if target is not None:
            got = hist.bytes_to_target(target)
            btt = str(got) if got is not None else "never"
            got_c = hist.bytes_to_target(target, bytes_key="coded_bytes")
            btt_coded = str(got_c) if got_c is not None else "never"
        rows(out, f"fl/{name}/{_tag(est, kw, temporal)}", us_round,
             f"final={final};mean_mse={np.nanmean(hist.mse):.6f};"
             f"bytes={hist.total_bytes};coded_bytes={hist.total_coded_bytes};"
             f"bytes_to_target={btt};bytes_to_target_coded={btt_coded}")


def client_temporal(out, n_rounds=20):
    """True per-client Rand-k-Temporal vs the broadcast variant on a drift
    task with persistent per-client offsets (the workload that separates
    them; codec.Temporal / ClientState memories)."""
    task = get_task("drift", n_clients=8, d=256, rho=0.95, omega=0.03,
                    client_bias=1.0)
    cohort = Cohort(n_clients=8)
    variants = [
        ("broadcast", codec.build("rand_k", k=26, d_block=256), True),
        ("per_client",
         codec.Pipeline([codec.RandK(k=26, d_block=256), codec.Temporal()]),
         False),
    ]
    for tag, pipe, broadcast in variants:
        t0 = time.time()
        _, hist = run_rounds(task, pipe, cohort,
                             RoundConfig(n_rounds=n_rounds, temporal=broadcast))
        us_round = (time.time() - t0) / n_rounds * 1e6
        rows(out, f"fl/drift_bias/rand_k_temporal.{tag}", us_round,
             f"mean_mse={np.nanmean(hist.mse[n_rounds // 2:]):.6f};"
             f"bytes={hist.total_bytes}")


def heterogeneous(out, n_rounds=6, d=256):
    """Mixed-budget cohort on local AND gspmd backends; ledgers must agree.

    The gspmd path decodes each budget group through dist.collectives — the
    group's k rides in ``payload.meta.budget``, so no backend special-casing
    — and the summed per-client byte ledger must equal the local backend's.
    """
    n = 8
    budgets = (13, 13, 26, 26, 26, 52, 52, 52)
    task = get_task("dme", n_clients=n, d=d, rho=0.9)
    cohort = Cohort(n_clients=n, budgets=budgets)
    for est, kw in [("rand_k", dict()), ("rand_proj_spatial", dict(transform="avg"))]:
        pipe = codec.build(est, k=26, d_block=d, **kw)
        totals = {}
        for backend in ("local", "gspmd"):
            t0 = time.time()
            _, hist = run_rounds(task, pipe, cohort,
                                 RoundConfig(n_rounds=n_rounds, backend=backend))
            us_round = (time.time() - t0) / n_rounds * 1e6
            totals[backend] = hist.total_bytes
            rows(out, f"fl/het_budget/{est}/{backend}", us_round,
                 f"mean_mse={np.nanmean(hist.mse):.6f};bytes={hist.total_bytes}")
        if totals["local"] != totals["gspmd"]:
            raise AssertionError(
                f"heterogeneous-budget ledger mismatch for {est}: "
                f"local={totals['local']} gspmd={totals['gspmd']}"
            )


def run(out):
    for name, (task_kw, d_block, k, n_rounds, target) in SETUPS.items():
        run_setup(out, name, task_kw, d_block, k, n_rounds, target)
    client_temporal(out)
    heterogeneous(out)


def smoke(out):
    """Reduced-size CI row set: correlated DME + a drifting task + the
    heterogeneous-budget local/gspmd ledger parity check."""
    run_setup(out, "dme", dict(n_clients=8, d=128, rho=0.9), 128, 16, 8, None)
    run_setup(out, "drift", dict(n_clients=8, d=128, rho=0.95, omega=0.03),
              128, 16, 8, None)
    heterogeneous(out, n_rounds=3, d=128)
