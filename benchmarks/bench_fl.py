"""repro.fl benchmark: MSE-vs-round and bytes-to-target-accuracy curves per
task x estimator (the paper's Fig. 4 measured at workload level, plus the
temporal-decoding comparison the paper's related work motivates).

Rows:
    fl/<task>/<estimator>[.temporal]     us_per_round    final=<metric>;
        mean_mse=<...>;bytes=<total>;bytes_to_target=<...|never>
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import EstimatorSpec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

from .common import rows

ESTIMATORS = [
    ("rand_k", dict(), False),
    ("rand_k_spatial", dict(transform="avg"), False),
    ("rand_proj_spatial", dict(transform="avg"), False),
    ("rand_proj_spatial", dict(transform="wavg"), False),
    ("rand_proj_spatial", dict(transform="avg"), True),  # temporal decode
]

# (task factory kwargs, d_block, k, rounds, bytes-to-target threshold)
SETUPS = {
    "dme": (dict(n_clients=8, d=256, rho=0.9), 256, 26, 40, None),
    "drift": (dict(n_clients=8, d=256, rho=0.95, omega=0.03), 256, 26, 40, None),
    "power_iteration": (dict(n_clients=10, d=1024, samples=4000), 1024, 102, 15, 0.5),
    "linear_regression": (dict(n_clients=10, d=512, samples=4000), 512, 51, 30, 0.05),
    "logistic_regression": (
        dict(n_clients=10, feat=64, samples=4000, scheme="dirichlet"), 1024, 102, 30, 0.5
    ),
}


def run_setup(out, name, task_kw, d_block, k, n_rounds, target, cohort=None):
    task = get_task(name, **task_kw)
    cohort = cohort or Cohort(n_clients=task.n_clients)
    for est, kw, temporal in ESTIMATORS:
        spec = EstimatorSpec(name=est, k=k, d_block=d_block, **kw)
        cfg = RoundConfig(n_rounds=n_rounds, temporal=temporal)
        t0 = time.time()
        state, hist = run_rounds(task, spec, cohort, cfg)
        us_round = (time.time() - t0) / n_rounds * 1e6
        final = "nan" if task.metric is None else f"{hist.metric[-1]:.5f}"
        btt = "n/a"
        if target is not None:
            got = hist.bytes_to_target(target)
            btt = str(got) if got is not None else "never"
        tag = f"{est}.{kw.get('transform', 'one')}" + (".temporal" if temporal else "")
        rows(out, f"fl/{name}/{tag}", us_round,
             f"final={final};mean_mse={np.nanmean(hist.mse):.6f};"
             f"bytes={hist.total_bytes};bytes_to_target={btt}")


def run(out):
    for name, (task_kw, d_block, k, n_rounds, target) in SETUPS.items():
        run_setup(out, name, task_kw, d_block, k, n_rounds, target)


def smoke(out):
    """Reduced-size CI row set: correlated DME + a drifting task."""
    run_setup(out, "dme", dict(n_clients=8, d=128, rho=0.9), 128, 16, 8, None)
    run_setup(out, "drift", dict(n_clients=8, d=128, rho=0.95, omega=0.03),
              128, 16, 8, None)
