#!/usr/bin/env python
"""Docs CI: markdown link check + executable snippet check.

    python tools/check_docs.py               # both checks
    python tools/check_docs.py --links-only  # fast, no deps (tier-1 test)
    python tools/check_docs.py --snippets-only

Link check: every relative markdown link in README.md, ROADMAP.md, and
docs/*.md must resolve to a file in the repo; ``#anchor`` fragments must
match a heading in the target (GitHub slugification). External links
(http/https/mailto) and GitHub web-relative links that escape the repo root
(e.g. the CI badge's ``../../actions/...``) are skipped. Every
``DESIGN.md §N[.M]`` section-number reference in the checked files must
also name a real ``## §N`` / ``### §N.M`` heading in docs/DESIGN.md.

Snippet check: ```python fenced blocks in README.md and the docs/*.md
reference set (SNIPPET_FILES) are executed — cumulatively per file, in one
subprocess with
``PYTHONPATH=src`` — so documented quickstarts cannot rot. A block is
exempted by putting ``<!-- docs-ci: skip -->`` on the line directly above
its opening fence (for deliberately illustrative fragments).
"""
from __future__ import annotations

import argparse
import functools
import glob
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_FILES = ["README.md", "ROADMAP.md"] + sorted(
    os.path.relpath(p, REPO) for p in glob.glob(os.path.join(REPO, "docs", "*.md"))
)
SNIPPET_FILES = ["README.md", "docs/DESIGN.md", "docs/API.md", "docs/KERNELS.md",
                 "docs/OBSERVABILITY.md"]

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_SKIP_MARK = "<!-- docs-ci: skip -->"


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slugification (best effort)."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)                  # inline formatting
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)  # links -> text
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    return re.sub(r"\s+", "-", h.strip())


@functools.cache  # one parse per file; paths are stable for the process
def heading_slugs(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # strip fenced code blocks so '# comment' lines aren't headings
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    slugs: set = set()
    for m in _HEADING_RE.finditer(text):
        slug, i = github_slug(m.group(1)), 0
        while (s := slug if i == 0 else f"{slug}-{i}") in slugs:
            i += 1
        slugs.add(s)
    return slugs


def check_links() -> list[str]:
    problems = []
    for rel in LINK_FILES:
        path = os.path.join(REPO, rel)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            if target.startswith("#"):
                if target[1:] not in heading_slugs(path):
                    problems.append(f"{rel}: broken anchor {target!r}")
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not resolved.startswith(REPO + os.sep):
                continue  # GitHub web-relative (badge links etc.)
            if not os.path.exists(resolved):
                problems.append(f"{rel}: broken link {m.group(1)!r}")
                continue
            if frag and resolved.endswith(".md"):
                if frag not in heading_slugs(resolved):
                    problems.append(
                        f"{rel}: broken anchor {m.group(1)!r}")
    return problems


_SECTION_HEADING_RE = re.compile(r"^#{2,3}\s+§([\d.]+)", re.MULTILINE)
_SECTION_REF_RE = re.compile(r"DESIGN\.md\s+§([\d.]+?)(?=[^\d.]|\.?$)")


def check_sections() -> list[str]:
    """Every ``DESIGN.md §N[.M]`` reference anywhere in the docs must name a
    section heading that actually exists in docs/DESIGN.md — prose and
    docstrings cite sections by number, so a renumbering that leaves stale
    references behind fails here instead of rotting silently."""
    with open(os.path.join(REPO, "docs", "DESIGN.md"), encoding="utf-8") as f:
        design = f.read()
    design = re.sub(r"```.*?```", "", design, flags=re.DOTALL)
    known = {m.group(1).rstrip(".") for m in _SECTION_HEADING_RE.finditer(design)}
    if not known:
        return ["docs/DESIGN.md: no '## §N' headings found (checker broken?)"]
    problems = []
    for rel in LINK_FILES:
        with open(os.path.join(REPO, rel), encoding="utf-8") as f:
            text = f.read()
        for m in _SECTION_REF_RE.finditer(text):
            num = m.group(1).rstrip(".")
            if num not in known:
                problems.append(
                    f"{rel}: reference to DESIGN.md §{num}, which has no "
                    f"matching heading (have: {', '.join(sorted(known))})")
    return problems


def python_blocks(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    blocks, cur, in_block, skip_next = [], [], False, False
    for line in lines:
        if not in_block and line.strip() == _SKIP_MARK:
            skip_next = True
            continue
        if not in_block and re.match(r"^```python\s*$", line.strip()):
            in_block, cur = True, []
            continue
        if in_block and line.strip() == "```":
            in_block = False
            if not skip_next:
                blocks.append("\n".join(cur))
            skip_next = False
            continue
        if in_block:
            cur.append(line)
        elif line.strip():
            skip_next = False  # marker binds to the NEXT fence only
    return blocks


def check_snippets() -> list[str]:
    problems = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    for rel in SNIPPET_FILES:
        blocks = python_blocks(os.path.join(REPO, rel))
        if not blocks:
            continue
        # cumulative: later blocks may use names the earlier ones defined
        program = "\n\n".join(blocks)
        try:
            proc = subprocess.run(
                [sys.executable, "-c", program], cwd=REPO, env=env,
                capture_output=True, text=True, timeout=480,
            )
        except subprocess.TimeoutExpired:
            problems.append(
                f"{rel}: its {len(blocks)} python block(s) did not finish "
                "within 480s — a documented snippet hangs or compiles "
                "something CI-sized")
            continue
        if proc.returncode != 0:
            tail = "\n".join(proc.stderr.strip().splitlines()[-12:])
            problems.append(
                f"{rel}: executing its {len(blocks)} python block(s) failed:\n"
                f"{tail}")
        else:
            print(f"  {rel}: {len(blocks)} python block(s) ran clean")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links-only", action="store_true")
    ap.add_argument("--snippets-only", action="store_true")
    args = ap.parse_args()
    problems = []
    if not args.snippets_only:
        print(f"link check over {', '.join(LINK_FILES)}")
        problems += check_links()
        problems += check_sections()
    if not args.links_only:
        print(f"snippet check over {', '.join(SNIPPET_FILES)}")
        problems += check_snippets()
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    if problems:
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
