#!/usr/bin/env python
"""Benchmark-artifact post-processing for CI (schema v1).

    python tools/bench_artifacts.py extract ownership  results/BENCH_smoke.json
    python tools/bench_artifacts.py extract kernels    results/BENCH_smoke.json
    python tools/bench_artifacts.py extract sparseproj results/BENCH_smoke.json
    python tools/bench_artifacts.py extract quant      results/BENCH_smoke.json
    python tools/bench_artifacts.py validate results/*.json

``extract`` pulls one benchmark section out of a full BENCH artifact into
its own derived artifact (OWNERSHIP_<mode>.json / KERNELS_<mode>.json /
SPARSEPROJ_<mode>.json), carrying the parent's schema stamp and run metadata
forward so a derived artifact is self-describing. Two extractions also
enforce perf gates: ``kernels`` requires every ``kernel_fused/...​/fused``
row to beat its ``/unfused`` sibling (a regression in kernels/srht_fused.py
or its dispatch fails CI here first), ``sparseproj`` requires the
SparseProj encode row to beat the SRHT encode row at equal budget in both
wall-clock and declared flops — the cheap-encode claim, continuously
measured — and ``quant`` requires every correlated-quantization MSE row to
strictly beat its int8 sibling at equal bytes AND every entropy-coded
payload size to stay <= its raw schema size.

``validate`` is the upload gate: every artifact CI archives must carry
``schema_version`` (currently 1), the ``run`` metadata stamp
(benchmarks.run.run_metadata — jax version/backend at minimum), and a
non-empty ``rows`` list with ``name``/``us_per_call`` fields. Schema-less
or metadata-less artifacts fail the job instead of uploading silently.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA_VERSION = 1
_REQUIRED_RUN_KEYS = ("jax_version", "jax_backend")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _fail(msg: str) -> "NoReturn":  # noqa: F821 - py3.10 typing comment only
    print(f"bench_artifacts: FAIL: {msg}", file=sys.stderr)
    raise SystemExit(1)


def validate_doc(doc: dict, path: str) -> None:
    if doc.get("schema_version") != SCHEMA_VERSION:
        _fail(f"{path}: schema_version={doc.get('schema_version')!r}, "
              f"want {SCHEMA_VERSION} (re-run benchmarks.run to stamp it)")
    run = doc.get("run")
    if not isinstance(run, dict):
        _fail(f"{path}: missing 'run' metadata stamp")
    missing = [k for k in _REQUIRED_RUN_KEYS if not run.get(k)]
    if missing:
        _fail(f"{path}: run metadata missing {missing}")
    rows = doc.get("rows")
    if not rows:
        _fail(f"{path}: empty or missing 'rows'")
    for r in rows:
        if "name" not in r or "us_per_call" not in r:
            _fail(f"{path}: malformed row {r!r}")


def _derived(doc: dict, rows: list) -> dict:
    return {"schema_version": SCHEMA_VERSION, "mode": doc["mode"],
            "run": doc["run"], "rows": rows}


def extract_ownership(doc: dict, path: str) -> dict:
    rows = [r for r in doc["rows"] if r["name"].startswith("ownership/")]
    if not rows:
        _fail(f"{path}: bench_systems.ownership produced no rows")
    return _derived(doc, rows)


def extract_kernels(doc: dict, path: str) -> dict:
    rows = [r for r in doc["rows"] if r["name"].startswith("kernel_fused/")]
    if not rows:
        _fail(f"{path}: bench_systems.fused_kernels produced no rows")
    by_name = {r["name"]: r["us_per_call"] for r in rows}
    for name, us in by_name.items():
        if not name.endswith("/fused"):
            continue
        sibling = name[: -len("/fused")] + "/unfused"
        if sibling not in by_name:
            _fail(f"{path}: missing unfused sibling for {name}")
        if us >= by_name[sibling]:
            _fail(f"fused decode regression: {name} {us:.1f}us >= "
                  f"{sibling} {by_name[sibling]:.1f}us")
    return _derived(doc, rows)


def _derived_field(row: dict, key: str, path: str) -> float:
    """Pull ``key=<number>`` out of a row's semicolon-packed derived column."""
    for part in row.get("derived", "").split(";"):
        if part.startswith(key + "="):
            return float(part[len(key) + 1:])
    _fail(f"{path}: row {row['name']!r} missing {key}= in derived column")


def extract_sparseproj(doc: dict, path: str) -> dict:
    """Cheap-encode gate: the ``sparseproj/encode/.../sparse_proj`` row must
    exist and beat its ``/srht`` sibling in BOTH wall-clock (us_per_call) and
    declared flops (flops_per_chunk in the derived column) — a missing row or
    a slower-than-SRHT sparse encode fails the bench-smoke job here."""
    rows = [r for r in doc["rows"] if r["name"].startswith("sparseproj/")]
    if not rows:
        _fail(f"{path}: bench_systems.sparseproj_encode produced no rows")
    by_name = {r["name"]: r for r in rows}
    gated = [n for n in by_name if n.endswith("/sparse_proj")]
    if not gated:
        _fail(f"{path}: no sparseproj/.../sparse_proj row to gate on")
    for name in gated:
        sibling = name[: -len("/sparse_proj")] + "/srht"
        if sibling not in by_name:
            _fail(f"{path}: missing srht sibling for {name}")
        sp, srht = by_name[name], by_name[sibling]
        if sp["us_per_call"] >= srht["us_per_call"]:
            _fail(f"sparse encode walltime regression: {name} "
                  f"{sp['us_per_call']:.1f}us >= {sibling} "
                  f"{srht['us_per_call']:.1f}us")
        sp_fl = _derived_field(sp, "flops_per_chunk", path)
        srht_fl = _derived_field(srht, "flops_per_chunk", path)
        if sp_fl >= srht_fl:
            _fail(f"sparse encode flops regression: {name} {sp_fl:.0f} >= "
                  f"{sibling} {srht_fl:.0f}")
    return _derived(doc, rows)


def extract_quant(doc: dict, path: str) -> dict:
    """Correlated-quantization + entropy-coding gates. Every
    ``quant/mse/.../correlated`` row must STRICTLY beat its ``/int8`` sibling
    on the ``mean_mse`` derived field — the anti-correlated rounding claim at
    identical wire bytes, continuously measured on the shared-support
    (identity / full-vector DME) setting where the cancellation is realized.
    Every ``quant/coded/`` row's exact entropy-coded stream length
    (``coded_bytes``) must not exceed its raw schema size (``raw_bytes``) —
    a coded payload that grew past its raw encoding fails the job."""
    rows = [r for r in doc["rows"] if r["name"].startswith("quant/")]
    if not rows:
        _fail(f"{path}: bench_systems.quant produced no rows")
    by_name = {r["name"]: r for r in rows}
    gated = [n for n in by_name
             if n.startswith("quant/mse/") and n.endswith("/correlated")]
    if not gated:
        _fail(f"{path}: no quant/mse/.../correlated row to gate on")
    for name in gated:
        sibling = name[: -len("/correlated")] + "/int8"
        if sibling not in by_name:
            _fail(f"{path}: missing int8 sibling for {name}")
        corr = _derived_field(by_name[name], "mean_mse", path)
        int8 = _derived_field(by_name[sibling], "mean_mse", path)
        if corr >= int8:
            _fail(f"correlated quantization regression: {name} "
                  f"mean_mse={corr:.9f} >= {sibling} mean_mse={int8:.9f} "
                  f"(anti-correlated rounding must win at equal bytes)")
    coded_rows = [n for n in by_name if n.startswith("quant/coded/")]
    if not coded_rows:
        _fail(f"{path}: no quant/coded/ rows to gate on")
    for name in coded_rows:
        cb = _derived_field(by_name[name], "coded_bytes", path)
        rb = _derived_field(by_name[name], "raw_bytes", path)
        if cb > rb:
            _fail(f"entropy-coded size exceeds raw schema size: {name} "
                  f"coded_bytes={cb:.0f} > raw_bytes={rb:.0f}")
    return _derived(doc, rows)


_SECTIONS = {"ownership": (extract_ownership, "OWNERSHIP"),
             "kernels": (extract_kernels, "KERNELS"),
             "sparseproj": (extract_sparseproj, "SPARSEPROJ"),
             "quant": (extract_quant, "QUANT")}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    ex = sub.add_parser("extract", help="pull a section into its own artifact")
    ex.add_argument("section", choices=sorted(_SECTIONS))
    ex.add_argument("bench_json")
    ex.add_argument("--out", default=None,
                    help="output path (default: <dir>/<SECTION>_<mode>.json)")
    va = sub.add_parser("validate", help="schema/metadata gate before upload")
    va.add_argument("paths", nargs="+")
    args = ap.parse_args()

    if args.cmd == "extract":
        doc = _load(args.bench_json)
        validate_doc(doc, args.bench_json)
        fn, stem = _SECTIONS[args.section]
        out_doc = fn(doc, args.bench_json)
        out = args.out or os.path.join(os.path.dirname(args.bench_json),
                                       f"{stem}_{doc['mode']}.json")
        with open(out, "w") as f:
            json.dump(out_doc, f, indent=1)
        print(f"bench_artifacts: {args.section}: {len(out_doc['rows'])} rows -> {out}")
    else:
        for path in args.paths:
            validate_doc(_load(path), path)
            print(f"bench_artifacts: OK {path}")


if __name__ == "__main__":
    main()
