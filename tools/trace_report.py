#!/usr/bin/env python
"""Trace CI gate: structural + ledger validation of a --trace JSON file.

    PYTHONPATH=src python -m repro.fl.run --task drift --smoke --trace t.json
    python tools/trace_report.py t.json

Asserts, against the Chrome-trace file the FL driver emitted
(src/repro/obs/trace.py documents the track layout):

1. The file is Perfetto-loadable Chrome Trace Event Format: a
   ``traceEvents`` list of ``ph: "X"|"M"|"C"`` events plus a ``metadata``
   object, every phase track named via ``thread_name`` metadata.
2. Every canonical round-phase track (``repro.obs.PHASES``) is present.
3. The ``round`` track carries exactly ``metadata.n_rounds`` spans, and
   every phase track has >= 1 event for every distinct round tag (each
   round's timeline is complete even when a phase is inactive — inactive
   phases emit zero-byte / zero-duration markers by contract).
4. THE LEDGER INVARIANT: the sum of ``args["bytes"]`` over all events
   equals ``metadata.ledger_total_bytes`` (History.total_bytes summed over
   the traced runs) EXACTLY — no float slack. ``bytes`` rides only on
   client_encode and stale_admission events; payload_route's modelled
   traffic uses ``bytes_intra_pod`` and the round summary uses
   ``wire_bytes`` precisely so this sum stays honest.
5. THE CODED-LEDGER INVARIANT (when ``metadata.ledger_coded_bytes`` is
   present): the sum of ``args["bytes_coded"]`` over the round-summary
   spans equals it exactly — the entropy-coded wire ledger
   (History.coded_bytes) is annotated under its OWN key so it never
   enters the raw-byte sum above.

Exit code is non-zero on any violation, with a per-check report.
"""
from __future__ import annotations

import argparse
import json
import sys

# keep in sync with src/repro/obs/trace.py (tools/ must run without
# PYTHONPATH=src in the docs job, so the canonical tuple is mirrored here
# and cross-checked against repro.obs when importable)
PHASES = (
    "round",
    "client_encode",
    "quantize",
    "payload_route",
    "owner_decode",
    "stale_admission",
    "temporal_update",
)


def _check_phases_in_sync() -> None:
    try:
        from repro.obs import PHASES as lib_phases
    except ImportError:
        return
    assert tuple(lib_phases) == PHASES, (
        f"tools/trace_report.py PHASES out of sync with repro.obs: "
        f"{lib_phases} != {PHASES}")


def report(doc: dict) -> list[str]:
    """Validate one trace document; returns a list of failure strings."""
    fails: list[str] = []
    events = doc.get("traceEvents")
    meta = doc.get("metadata")
    if not isinstance(events, list) or not isinstance(meta, dict):
        return ["not a Chrome-trace file: need traceEvents list + metadata obj"]

    ok_ph = {"X", "M", "C"}
    bad = [e for e in events if e.get("ph") not in ok_ph]
    if bad:
        fails.append(f"{len(bad)} events with unexpected ph (first: {bad[0]!r})")

    # track names come from thread_name metadata events
    tracks = {e["tid"]: e["args"]["name"] for e in events
              if e.get("ph") == "M" and e.get("name") == "thread_name"}
    spans = [e for e in events if e.get("ph") == "X"]
    by_track: dict[str, list[dict]] = {}
    for e in spans:
        by_track.setdefault(tracks.get(e["tid"], f"tid{e['tid']}"), []).append(e)

    missing = [p for p in PHASES if p not in by_track]
    if missing:
        fails.append(f"missing phase tracks: {missing} (have {sorted(by_track)})")

    n_rounds = meta.get("n_rounds")
    if not isinstance(n_rounds, int) or n_rounds <= 0:
        fails.append(f"metadata.n_rounds missing/invalid: {n_rounds!r}")
    else:
        got = len(by_track.get("round", []))
        if got != n_rounds:
            fails.append(f"round track has {got} spans, metadata says {n_rounds}")

    # one event per phase per distinct round tag (repeated tags are fine:
    # --compare runs share the timeline, each tagging its own rounds 0..T-1)
    round_tags = sorted({e["args"].get("round") for e in spans
                         if e["args"].get("round") is not None})
    if not round_tags:
        fails.append("no events carry a round tag")
    for phase in PHASES:
        tagged = {e["args"].get("round") for e in by_track.get(phase, [])}
        holes = [t for t in round_tags if t not in tagged]
        if holes and phase in by_track:
            fails.append(f"phase {phase!r} has no event for round(s) {holes}")

    # the ledger invariant — exact integer equality
    traced = sum(e["args"]["bytes"] for e in spans if "bytes" in e["args"])
    ledger = meta.get("ledger_total_bytes")
    if ledger is None:
        fails.append("metadata.ledger_total_bytes missing")
    elif int(traced) != int(ledger) or traced != int(traced):
        fails.append(f"byte-ledger mismatch: trace sums {traced}, "
                     f"History.total_bytes says {ledger}")

    # the coded ledger, when traced, must match under its own key
    ledger_coded = meta.get("ledger_coded_bytes")
    if ledger_coded is not None:
        coded = sum(e["args"]["bytes_coded"] for e in spans
                    if "bytes_coded" in e["args"])
        if int(coded) != int(ledger_coded) or coded != int(coded):
            fails.append(f"coded-ledger mismatch: trace sums {coded}, "
                         f"History.coded_bytes says {ledger_coded}")

    # bytes must ride only on the two wire-crossing tracks
    offenders = sorted({tracks.get(e["tid"], "?") for e in spans
                        if "bytes" in e["args"]
                        and tracks.get(e["tid"]) not in
                        ("client_encode", "stale_admission")})
    if offenders:
        fails.append(f"'bytes' arg on non-wire tracks {offenders} "
                     "(would double-count the ledger)")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace_json")
    args = ap.parse_args()
    _check_phases_in_sync()
    with open(args.trace_json) as f:
        doc = json.load(f)
    fails = report(doc)
    n = len([e for e in doc.get("traceEvents", []) if e.get("ph") == "X"])
    if fails:
        for msg in fails:
            print(f"trace_report: FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    meta = doc["metadata"]
    print(f"trace_report: OK {args.trace_json}: {n} spans, "
          f"{meta['n_rounds']} rounds, "
          f"{meta['ledger_total_bytes']} ledgered bytes (exact)")


if __name__ == "__main__":
    main()
