"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used by the per-kernel allclose
tests (tests/test_kernels.py) and as the CPU fallback path in
``repro.core.hadamard``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def hadamard_matrix(d: int, dtype=np.float32) -> np.ndarray:
    """Sylvester-ordered Hadamard matrix H_d with +-1 entries (d = 2**m)."""
    if d & (d - 1) != 0 or d < 1:
        raise ValueError(f"d must be a power of two, got {d}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return h.astype(dtype)


@functools.partial(jnp.vectorize, signature="(d)->(d)")
def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalised Fast Walsh-Hadamard Transform along the last axis.

    Computes ``H_d @ x`` for the Sylvester-ordered Hadamard matrix via the
    classic log2(d)-stage butterfly. O(d log d) adds. Matches
    ``hadamard_matrix(d) @ x`` exactly (integer arithmetic on +-1 weights).
    """
    (d,) = x.shape
    if d & (d - 1) != 0:
        raise ValueError(f"last dim must be a power of two, got {d}")
    h = 1
    while h < d:
        x = x.reshape(d // (2 * h), 2, h)
        a = x[:, 0, :]
        b = x[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1).reshape(d)
        h *= 2
    return x


def srht_encode_ref(x: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Reference SRHT encode: (1/sqrt(d)) * (H @ (signs * x))[rows].

    x:     (..., d)   input vectors
    signs: (d,)       Rademacher +-1 diagonal of D_i
    rows:  (k,)       int32 row subset of E_i (sampled without replacement)
    returns (..., k)
    """
    d = x.shape[-1]
    t = fwht_ref(x * signs) * (1.0 / np.sqrt(d))
    return jnp.take(t, rows, axis=-1)


def flash_attention_ref(q, k, v, *, rep: int, window: int = 0, q_offset: int = 0):
    """Oracle for the flash-attention kernel.

    q: (N_q, Sq, dh); k, v: (N_kv, Sk, dh); N_q = N_kv * rep.
    Causal over absolute positions (q at q_offset + i attends to j <= pos).
    """
    nq, sq, dh = q.shape
    k = jnp.repeat(k, rep, axis=0)
    v = jnp.repeat(v, rep, axis=0)
    sk = k.shape[1]
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(dh)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p, v.astype(jnp.float32)).astype(q.dtype)


def srht_decode_ref(u: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray, d: int) -> jnp.ndarray:
    """Reference SRHT adjoint: G^T u = (1/sqrt(d)) * signs * (H @ scatter(u)).

    u:    (..., k)
    returns (..., d)
    """
    full = jnp.zeros(u.shape[:-1] + (d,), u.dtype)
    full = full.at[..., rows].set(u)
    return fwht_ref(full) * (signs * (1.0 / np.sqrt(d)))


# ------------------------------------------------- fused-kernel oracles
# Ground truth for kernels/srht_fused.py: the batched per-row-signs FWHT
# (encode side) and the client-summed adjoint / Gram applies (decode side).
# Scale is applied as an explicit elementwise multiply AFTER the transform —
# the fused kernels place it identically, which is what makes the bitwise
# golden tests in tests/test_kernels.py possible (integer-valued inputs keep
# every +-1 Hadamard partial sum exact in float32).


def srht_scatter_ref(z: jnp.ndarray, rows: jnp.ndarray, d: int) -> jnp.ndarray:
    """Scatter payload values to full width: out[..., rows[..., j]] = z[..., j].

    z: (..., k); rows: int32, broadcastable to z's shape. -> (..., d)
    """
    z = jnp.asarray(z)
    rows = jnp.broadcast_to(rows, z.shape)
    full = jnp.zeros(z.shape[:-1] + (d,), z.dtype)
    idx = tuple(
        jnp.arange(s).reshape((1,) * i + (s,) + (1,) * (z.ndim - i - 1))
        for i, s in enumerate(z.shape[:-1])
    )
    return full.at[idx + (rows,)].set(z)


def fwht_rowsigns_ref(
    x: jnp.ndarray,
    signs: jnp.ndarray | None,
    *,
    sign_pre: bool = False,
    sign_post: bool = False,
    scale: float = 1.0,
) -> jnp.ndarray:
    """Batched FWHT with PER-ROW Rademacher diagonals:
    ``scale * [signs *] H ([signs *] x)``.

    x: (..., d); signs broadcastable to x (one diagonal per leading index).
    ``sign_pre`` flips before the transform (encode side), ``sign_post``
    after (decode/adjoint side).
    """
    t = x * signs if sign_pre else x
    t = fwht_ref(t)
    if sign_post:
        t = t * signs
    if scale != 1.0:
        t = t * jnp.asarray(scale, t.dtype)
    return t


def srht_encode_batch_ref(x: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Batched SRHT encode with per-row draws:
    ``(1/sqrt(d)) (H (signs * x))[rows]`` row-for-row.

    x, signs: (..., d); rows: (..., k) int32 (leading dims aligned)."""
    d = x.shape[-1]
    t = fwht_rowsigns_ref(x, signs, sign_pre=True, scale=1.0 / np.sqrt(d))
    return jnp.take_along_axis(t, rows, axis=-1)


def srht_decode_sum_ref(
    z: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray, d: int
) -> jnp.ndarray:
    """Client-summed SRHT adjoint ``y = sum_i G_i^T z_i`` per chunk.

    z: (n, C, k); signs: (n, C|1, d); rows: (n, C|1, k). -> (C, d)
    """
    full = srht_scatter_ref(z, rows, d)  # (n, C, d)
    out = fwht_rowsigns_ref(full, signs, sign_post=True, scale=1.0 / np.sqrt(d))
    return jnp.sum(out, axis=0)


# --------------------------------------------- very-sparse projection oracles
# Ground truth for the SparseProj codec (core/estimators/sparse_proj.py): each
# row of G holds ``nnz`` signed entries at key-derived columns, so encode is a
# gather+reduce, the adjoint is a scatter-add, and the Gram apply composes the
# two. Scales (1/sqrt(nnz), 1/nnz) are applied by the ops layer as explicit
# post-multiplies, mirroring the SRHT oracles above.


def sparse_encode_ref(x: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray) -> jnp.ndarray:
    """Unscaled sparse-projection encode ``out[..., r] = sum_t signs[..., r, t]
    * x[..., cols[..., r, t]]``.

    x: (..., d); signs, cols: (..., k, nnz) — leading dims broadcast-aligned
    (one independent draw per leading index). -> (..., k)
    """
    lead = jnp.broadcast_shapes(x.shape[:-1], cols.shape[:-2], signs.shape[:-2])
    xb = jnp.broadcast_to(x, lead + x.shape[-1:])
    cb = jnp.broadcast_to(cols, lead + cols.shape[-2:])
    t = jnp.take_along_axis(xb[..., None, :], cb, axis=-1)  # (..., k, nnz)
    return jnp.sum(t * signs, axis=-1)


def sparse_scatter_add_ref(
    z: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray, d: int
) -> jnp.ndarray:
    """Unscaled sparse-projection adjoint ``out[..., cols[..., r, t]] +=
    signs[..., r, t] * z[..., r]``.

    z: (..., k); signs, cols: (..., k, nnz). Columns are sampled with
    replacement, so they repeat both across AND within rows; the scatter-ADD
    merges every repeat (within-row duplicates sum their signs), unlike
    ``srht_scatter_ref``'s disjoint-rows ``set``. -> (..., d)
    """
    z = jnp.asarray(z)
    contrib = z[..., None] * signs                       # (..., k, nnz)
    cols = jnp.broadcast_to(cols, contrib.shape)
    cf = cols.reshape(*cols.shape[:-2], -1)              # (..., k*nnz)
    vf = contrib.reshape(*contrib.shape[:-2], -1)
    full = jnp.zeros(vf.shape[:-1] + (d,), vf.dtype)
    idx = tuple(
        jnp.arange(s).reshape((1,) * i + (s,) + (1,) * (vf.ndim - i - 1))
        for i, s in enumerate(vf.shape[:-1])
    )
    return full.at[idx + (cf,)].add(vf)


def sparse_gram_apply_ref(v: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray) -> jnp.ndarray:
    """Unscaled matrix-free ``sum_i G_i^T G_i v`` for sparse maps (caller
    multiplies by 1/nnz, the product of the two 1/sqrt(nnz) row scales).

    v: (C, d); signs, cols: (n, C|1, k, nnz). -> (C, d)
    """
    z = sparse_encode_ref(v[None], signs, cols)                      # (n, C, k)
    out = sparse_scatter_add_ref(z, signs, cols, v.shape[-1])        # (n, C, d)
    return jnp.sum(out, axis=0)


def srht_gram_apply_ref(v: jnp.ndarray, signs: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Matrix-free ``S v = sum_i G_i^T G_i v`` for SRHT maps.

    Because G_i^T G_i = (1/d) D_i H^T E_i^T E_i H D_i, the apply is two FWHTs
    with a coordinate mask between them, summed over clients:

        S v = (1/d) sum_i signs_i * H (mask_i * H (signs_i * v))

    v: (C, d); signs, mask: (n, C|1, d). -> (C, d)
    """
    d = v.shape[-1]
    t = fwht_rowsigns_ref(v[None], signs, sign_pre=True)       # (n, C, d)
    t = fwht_rowsigns_ref(mask * t, signs, sign_post=True, scale=1.0 / d)
    return jnp.sum(t, axis=0)
