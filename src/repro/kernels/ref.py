"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used by the per-kernel allclose
tests (tests/test_kernels.py) and as the CPU fallback path in
``repro.core.hadamard``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def hadamard_matrix(d: int, dtype=np.float32) -> np.ndarray:
    """Sylvester-ordered Hadamard matrix H_d with +-1 entries (d = 2**m)."""
    if d & (d - 1) != 0 or d < 1:
        raise ValueError(f"d must be a power of two, got {d}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return h.astype(dtype)


@functools.partial(jnp.vectorize, signature="(d)->(d)")
def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalised Fast Walsh-Hadamard Transform along the last axis.

    Computes ``H_d @ x`` for the Sylvester-ordered Hadamard matrix via the
    classic log2(d)-stage butterfly. O(d log d) adds. Matches
    ``hadamard_matrix(d) @ x`` exactly (integer arithmetic on +-1 weights).
    """
    (d,) = x.shape
    if d & (d - 1) != 0:
        raise ValueError(f"last dim must be a power of two, got {d}")
    h = 1
    while h < d:
        x = x.reshape(d // (2 * h), 2, h)
        a = x[:, 0, :]
        b = x[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1).reshape(d)
        h *= 2
    return x


def srht_encode_ref(x: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Reference SRHT encode: (1/sqrt(d)) * (H @ (signs * x))[rows].

    x:     (..., d)   input vectors
    signs: (d,)       Rademacher +-1 diagonal of D_i
    rows:  (k,)       int32 row subset of E_i (sampled without replacement)
    returns (..., k)
    """
    d = x.shape[-1]
    t = fwht_ref(x * signs) * (1.0 / np.sqrt(d))
    return jnp.take(t, rows, axis=-1)


def flash_attention_ref(q, k, v, *, rep: int, window: int = 0, q_offset: int = 0):
    """Oracle for the flash-attention kernel.

    q: (N_q, Sq, dh); k, v: (N_kv, Sk, dh); N_q = N_kv * rep.
    Causal over absolute positions (q at q_offset + i attends to j <= pos).
    """
    nq, sq, dh = q.shape
    k = jnp.repeat(k, rep, axis=0)
    v = jnp.repeat(v, rep, axis=0)
    sk = k.shape[1]
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(dh)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", p, v.astype(jnp.float32)).astype(q.dtype)


def srht_decode_ref(u: jnp.ndarray, signs: jnp.ndarray, rows: jnp.ndarray, d: int) -> jnp.ndarray:
    """Reference SRHT adjoint: G^T u = (1/sqrt(d)) * signs * (H @ scatter(u)).

    u:    (..., k)
    returns (..., d)
    """
    full = jnp.zeros(u.shape[:-1] + (d,), u.dtype)
    full = full.at[..., rows].set(u)
    return fwht_ref(full) * (signs * (1.0 / np.sqrt(d)))
