"""Pallas TPU kernel: batched Fast Walsh-Hadamard Transform (FWHT).

This is the compute hot-spot of the paper's SRHT encoding/decoding
(G_i = (1/sqrt(d)) E_i H D_i): every encode applies ``H @ (D_i x)`` and every
decode applies ``H @ scatter(payload)``.

TPU adaptation (see docs/DESIGN.md §3.2): instead of the classic log2(d)-stage
butterfly (VPU add/sub, memory-bound, one HBM round-trip per stage under XLA
fusion limits) we use the Kronecker factorisation of the Sylvester Hadamard
matrix

    H_d = H_a (x) H_b,        d = a*b,  b = min(d, 128)

so that the whole transform becomes two *matmuls* against tiny constant
+-1 matrices, executed on the MXU with the (rows, d) tile resident in VMEM:

    X   = x.reshape(rows*a, b)
    Y   = X @ H_b                      # lane-dim mix     (MXU, b=128 lanes)
    Z   = H_a @ Y.reshape(rows, a, b)  # sublane-dim mix  (MXU)
    out = Z.reshape(rows, d)

The reshape (rows, a*b) -> (rows*a, b) moves no data when b is a multiple of
the 128-lane width; the stage-2 contraction only permutes major dims. The
Rademacher sign flip (D_i) and the 1/sqrt(d) scale are fused into the kernel
(signs multiply on load; scale folded into the H_b constant), so an SRHT
encode is a single VMEM-resident pass over the data.

Validated against the pure-jnp oracle (kernels/ref.py) in interpret mode on
CPU; on TPU the same kernel lowers via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _kernel(h_a_ref, h_b_ref, s_ref, x_ref, o_ref, *, a: int, b: int, with_signs: bool):
    x = x_ref[...].astype(jnp.float32)  # (bt, d)
    bt = x.shape[0]
    if with_signs:
        x = x * s_ref[...].astype(jnp.float32)  # (1, d) broadcast over rows
    # stage 1: mix within contiguous groups of b (lane dimension).
    xg = x.reshape(bt * a, b)
    y = jax.lax.dot_general(
        xg, h_b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bt*a, b); H_b symmetric so X @ H_b == X @ H_b^T
    if a > 1:
        # stage 2: mix across the a groups (sublane dimension).
        y3 = y.reshape(bt, a, b)
        z = jax.lax.dot_general(
            h_a_ref[...], y3,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (a, bt, b)
        out = z.transpose(1, 0, 2).reshape(bt, a * b)
    else:
        out = y.reshape(bt, b)
    o_ref[...] = out.astype(o_ref.dtype)


def _split_dims(d: int) -> tuple[int, int]:
    if d & (d - 1) != 0 or d < 2:
        raise ValueError(f"FWHT dim must be a power of two >= 2, got {d}")
    b = min(d, 128)
    return d // b, b


def _pick_block_rows(n_rows: int, d: int) -> int:
    # keep in/out tiles + constants well under ~8 MiB of VMEM.
    budget = 2 * 1024 * 1024  # floats per tile buffer
    bt = max(8, budget // d)
    bt = 1 << (bt.bit_length() - 1)  # round down to power of two
    return int(min(bt, max(8, n_rows)))


@functools.partial(
    jax.jit, static_argnames=("with_signs", "scale", "block_rows", "interpret")
)
def fwht_pallas(
    x: jnp.ndarray,
    signs: jnp.ndarray | None = None,
    *,
    with_signs: bool = False,
    scale: float = 1.0,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched FWHT over the last axis: ``scale * H_d @ (signs? * x)``.

    x:     (rows, d), d a power of two (>=2); rows arbitrary (padded to tile).
    signs: optional (d,) +-1 Rademacher diagonal, fused on load.
    scale: constant folded into the H_b stage (e.g. 1/sqrt(d) for SRHT).
    """
    rows, d = x.shape
    a, b = _split_dims(d)
    bt = block_rows or _pick_block_rows(rows, d)
    pad = (-rows) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_tiles = x.shape[0] // bt

    h_a = jnp.asarray(_ref.hadamard_matrix(a), jnp.float32)
    h_b = jnp.asarray(_ref.hadamard_matrix(b) * scale, jnp.float32)
    if signs is None:
        signs2 = jnp.ones((1, d), jnp.float32)
    else:
        signs2 = signs.reshape(1, d).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, a=a, b=b, with_signs=with_signs and signs is not None),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(h_a, h_b, signs2, x)
    if pad:
        out = out[:rows]
    return out
