"""Pallas TPU kernel: flash attention (tiled online-softmax).

Motivation (docs/EXPERIMENTS.md §Roofline): the memory term of every attention
arch is dominated by the materialised (tokens x S x heads) score tensor —
XLA cannot keep it in VMEM across the matmul -> softmax -> matmul boundary,
and the pure-JAX kv-block scan still round-trips the f32 accumulator
through HBM once per kv block. This kernel keeps the (q_tile, dh)
accumulator and (q_tile, kv_tile) score tile resident in VMEM scratch for
the whole kv sweep: HBM traffic drops to Q/K/V reads + O writes, bounded
VMEM at any sequence length.

Grid: (batch*n_q_heads, q_tiles, kv_tiles) — kv innermost, revisiting the
same output block with carry state in VMEM scratch (the standard Pallas
flash pattern). GQA is handled in the K/V BlockSpec index maps
(kv head = q head // rep), so no K/V repeat is ever materialised. Causal /
sliding-window masks are arithmetic on absolute positions.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e9


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, kv_tile: int, q_tile: int, window: int,
            q_offset: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (qt, dh)
    qt = q.shape[0]
    q_pos = q_offset + i * q_tile + jax.lax.broadcasted_iota(jnp.int32, (qt, 1), 0)
    k_blk = k_ref[0].astype(jnp.float32)  # (kv_tile, dh)
    v_blk = v_ref[0].astype(jnp.float32)
    k_pos = j * kv_tile + jax.lax.broadcasted_iota(jnp.int32, (1, kv_tile), 1)

    s = jax.lax.dot_general(
        q, k_blk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (qt, kv_tile)
    mask = k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v_blk, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("rep", "window", "q_offset", "q_tile", "kv_tile", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,   # (N_q, Sq, dh)   N_q = batch * n_q_heads
    k: jnp.ndarray,   # (N_kv, Sk, dh)  N_kv = batch * n_kv_heads
    v: jnp.ndarray,
    *,
    rep: int,          # n_q_heads // n_kv_heads
    window: int = 0,
    q_offset: int = 0,
    q_tile: int = 128,
    kv_tile: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    nq, sq, dh = q.shape
    _, sk, _ = k.shape
    q_tile = min(q_tile, sq)
    kv_tile = min(kv_tile, sk)
    assert sq % q_tile == 0 and sk % kv_tile == 0, (sq, q_tile, sk, kv_tile)
    n_kv = sk // kv_tile
    grid = (nq, sq // q_tile, n_kv)

    return pl.pallas_call(
        functools.partial(
            _kernel, n_kv=n_kv, kv_tile=kv_tile, q_tile=q_tile,
            window=window, q_offset=q_offset, scale=1.0 / math.sqrt(dh),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_tile, dh), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda n, i, j, rep=rep: (n // rep, j, 0)),
            pl.BlockSpec((1, kv_tile, dh), lambda n, i, j, rep=rep: (n // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_tile, dh), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_tile, 1), jnp.float32),
            pltpu.VMEM((q_tile, 1), jnp.float32),
            pltpu.VMEM((q_tile, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
