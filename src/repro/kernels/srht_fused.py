"""Pallas TPU kernels: fused SRHT encode/decode batched over (clients x chunks).

These kernels close the decode gap for the paper's headline estimator
(`rand_proj_spatial`): the server-side inverse SRHT

    y_c = sum_i G_i^T z_ic,      G_i = (1/sqrt(d)) E_i H D_i

used to run as a per-chunk Python loop over unfused scatter + FWHT + sign
multiplies. Here the whole reduction is ONE kernel launch:

  * `fwht_rowsigns_pallas`   — encode-side mirror fusion: per-row Rademacher
    sign flip + FWHT (+ optional post-signs) in one VMEM-resident pass. The
    coordinate subsample (E_i gather) stays in XLA where it fuses with the
    payload pack.
  * `srht_decode_sum_pallas` — inverse-SRHT + sign/scale + scatter-add over
    clients. Grid is (chunk_tiles, n_clients) with the CLIENT axis rightmost
    (fastest-varying), so each output tile is visited by all n clients
    consecutively and accumulated in place (`@pl.when(i == 0)` initialises).
  * `srht_gram_apply_pallas` — matrix-free S v = sum_i G_i^T G_i v: two FWHTs
    with a coordinate mask between them, same accumulation scheme. This is the
    inner product of the fused decode's conjugate-gradient resolvent solve
    (docs/DESIGN.md §3.5).

All three reuse the Kronecker-factored MXU tiling of `kernels/fwht.py`
(H_d = H_a (x) H_b, two dot_generals against tiny +-1 constants). Unlike
`fwht_pallas`, the 1/sqrt(d) scale is NOT folded into the H_b constant but
applied as an explicit elementwise multiply after the transform — exactly
where `kernels/ref.py` applies it — so interpret mode is bit-exact against
the oracle composition (see the golden tests in tests/test_kernels.py).

VMEM budget: a (block_chunks, d) tile per operand plus the (a, a), (b, b)
Hadamard constants; `_pick_block_rows` keeps each buffer under 2M floats
(~8 MiB), identical to the fwht.py policy. See docs/KERNELS.md for the
worked walkthrough.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref
from .fwht import _pick_block_rows, _split_dims


def _fwht_tile(x, h_a_ref, h_b_ref, *, a: int, b: int):
    """Unnormalised H_d @ x for a (bt, d) tile via the two-matmul Kronecker
    factorisation (same dataflow as fwht._kernel)."""
    bt = x.shape[0]
    xg = x.reshape(bt * a, b)
    y = jax.lax.dot_general(
        xg, h_b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if a > 1:
        y3 = y.reshape(bt, a, b)
        z = jax.lax.dot_general(
            h_a_ref[...], y3,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return z.transpose(1, 0, 2).reshape(bt, a * b)
    return y.reshape(bt, b)


def _rowsigns_kernel(
    h_a_ref, h_b_ref, s_ref, x_ref, o_ref,
    *, a: int, b: int, sign_pre: bool, sign_post: bool, scale: float,
):
    x = x_ref[...].astype(jnp.float32)  # (bt, d)
    s = s_ref[...].astype(jnp.float32)  # (bt, d) — one diagonal PER ROW
    if sign_pre:
        x = x * s
    t = _fwht_tile(x, h_a_ref, h_b_ref, a=a, b=b)
    if sign_post:
        t = t * s
    if scale != 1.0:
        t = t * jnp.float32(scale)
    o_ref[...] = t.astype(o_ref.dtype)


def _decode_sum_kernel(
    h_a_ref, h_b_ref, s_ref, u_ref, o_ref, *, a: int, b: int, scale: float
):
    i = pl.program_id(1)  # client index — rightmost grid axis, fastest-varying
    u = u_ref[0].astype(jnp.float32)          # (bt, d) scattered payloads
    t = _fwht_tile(u, h_a_ref, h_b_ref, a=a, b=b)
    t = t * s_ref[0].astype(jnp.float32)      # (bt, d) or broadcast (1, d)
    if scale != 1.0:
        t = t * jnp.float32(scale)
    t = t.astype(o_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = t

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += t


def _gram_apply_kernel(
    h_a_ref, h_b_ref, s_ref, m_ref, v_ref, o_ref, *, a: int, b: int, scale: float
):
    i = pl.program_id(1)
    v = v_ref[...].astype(jnp.float32)        # (bt, d) — same tile for every i
    s = s_ref[0].astype(jnp.float32)
    t = _fwht_tile(v * s, h_a_ref, h_b_ref, a=a, b=b)
    t = t * m_ref[0].astype(jnp.float32)      # keep only client i's coordinates
    t = _fwht_tile(t, h_a_ref, h_b_ref, a=a, b=b)
    t = t * s
    if scale != 1.0:
        t = t * jnp.float32(scale)
    t = t.astype(o_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = t

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += t


def _hadamard_consts(a: int, b: int):
    h_a = jnp.asarray(_ref.hadamard_matrix(a), jnp.float32)
    h_b = jnp.asarray(_ref.hadamard_matrix(b), jnp.float32)
    return h_a, h_b


def _pad_chunk_axis(x: jnp.ndarray, axis: int, to_multiple: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % to_multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("sign_pre", "sign_post", "scale", "block_rows", "interpret"),
)
def fwht_rowsigns_pallas(
    x: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    sign_pre: bool = False,
    sign_post: bool = False,
    scale: float = 1.0,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused batched FWHT with per-row Rademacher diagonals.

    ``out = scale * [signs *] H_d ([signs *] x)`` with x, signs of shape
    (rows, d) — row r uses diagonal signs[r] (contrast `fwht_pallas`, which
    shares ONE diagonal across all rows). Oracle: ref.fwht_rowsigns_ref.
    """
    rows, d = x.shape
    a, b = _split_dims(d)
    bt = block_rows or _pick_block_rows(rows, d)
    x = _pad_chunk_axis(x, 0, bt)
    signs = _pad_chunk_axis(signs.astype(x.dtype), 0, bt)
    n_tiles = x.shape[0] // bt
    h_a, h_b = _hadamard_consts(a, b)

    out = pl.pallas_call(
        functools.partial(
            _rowsigns_kernel, a=a, b=b,
            sign_pre=sign_pre, sign_post=sign_post, scale=scale,
        ),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(h_a, h_b, signs, x)
    return out[:rows]


@functools.partial(jax.jit, static_argnames=("scale", "block_rows", "interpret"))
def srht_decode_sum_pallas(
    u: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    scale: float,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused inverse-SRHT + sign/scale + scatter-add over clients.

    u:     (n, C, d) payloads already scattered to full width
    signs: (n, C, d) per-(client, chunk) diagonals, or (n, 1, d) when the
           chunk dimension shares one draw per client (shared_randomness)
    returns (C, d) = ``sum_i scale * signs_i * (H_d @ u_i)``.
    Oracle: ref.srht_decode_sum_ref (minus the scatter, done here by caller).
    """
    n, c, d = u.shape
    shared = signs.shape[1] == 1
    a, b = _split_dims(d)
    bt = block_rows or _pick_block_rows(c, d)
    bt = min(bt, max(8, c))
    u = _pad_chunk_axis(u, 1, bt)
    if not shared:
        signs = _pad_chunk_axis(signs, 1, bt)
    n_ctiles = u.shape[1] // bt
    h_a, h_b = _hadamard_consts(a, b)

    if shared:
        s_spec = pl.BlockSpec((1, 1, d), lambda ct, i: (i, 0, 0))
    else:
        s_spec = pl.BlockSpec((1, bt, d), lambda ct, i: (i, ct, 0))

    out = pl.pallas_call(
        functools.partial(_decode_sum_kernel, a=a, b=b, scale=scale),
        grid=(n_ctiles, n),
        in_specs=[
            pl.BlockSpec((a, a), lambda ct, i: (0, 0)),
            pl.BlockSpec((b, b), lambda ct, i: (0, 0)),
            s_spec,
            pl.BlockSpec((1, bt, d), lambda ct, i: (i, ct, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ct, i: (ct, 0)),
        out_shape=jax.ShapeDtypeStruct((u.shape[1], d), jnp.float32),
        interpret=interpret,
    )(h_a, h_b, signs.astype(jnp.float32), u.astype(jnp.float32))
    return out[:c]


@functools.partial(jax.jit, static_argnames=("scale", "block_rows", "interpret"))
def srht_gram_apply_pallas(
    v: jnp.ndarray,
    signs: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    scale: float,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused matrix-free ``S v = sum_i G_i^T G_i v`` for SRHT maps.

    v:     (C, d) one vector per chunk
    signs: (n, C, d) or (n, 1, d) Rademacher diagonals
    mask:  (n, C, d) or (n, 1, d) 0/1 indicators of each draw's rows
    scale: 1/d for G_i = (1/sqrt(d)) E_i H D_i
    returns (C, d). Oracle: ref.srht_gram_apply_ref.
    """
    c, d = v.shape
    n = signs.shape[0]
    a, b = _split_dims(d)
    bt = block_rows or _pick_block_rows(c, d)
    bt = min(bt, max(8, c))
    v = _pad_chunk_axis(v, 0, bt)
    if signs.shape[1] != 1:
        signs = _pad_chunk_axis(signs, 1, bt)
    if mask.shape[1] != 1:
        mask = _pad_chunk_axis(mask, 1, bt)
    n_ctiles = v.shape[0] // bt
    h_a, h_b = _hadamard_consts(a, b)

    def _bc_spec(arr):
        if arr.shape[1] == 1:
            return pl.BlockSpec((1, 1, d), lambda ct, i: (i, 0, 0))
        return pl.BlockSpec((1, bt, d), lambda ct, i: (i, ct, 0))

    out = pl.pallas_call(
        functools.partial(_gram_apply_kernel, a=a, b=b, scale=scale),
        grid=(n_ctiles, n),
        in_specs=[
            pl.BlockSpec((a, a), lambda ct, i: (0, 0)),
            pl.BlockSpec((b, b), lambda ct, i: (0, 0)),
            _bc_spec(signs),
            _bc_spec(mask),
            pl.BlockSpec((bt, d), lambda ct, i: (ct, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ct, i: (ct, 0)),
        out_shape=jax.ShapeDtypeStruct((v.shape[0], d), jnp.float32),
        interpret=interpret,
    )(h_a, h_b, signs.astype(jnp.float32), mask.astype(jnp.float32),
      v.astype(jnp.float32))
    return out[:c]
