"""Jit'd public wrappers around the Pallas kernels, with CPU fallbacks.

``fwht``        - batched Walsh-Hadamard transform over the last axis.
``srht_encode`` - fused SRHT encode:  (1/sqrt(d)) (H (signs*x))[rows].
``srht_decode`` - SRHT adjoint:       (1/sqrt(d)) signs * (H scatter(u)).

Fused batched ops behind the rand_proj_spatial fast path (docs/KERNELS.md):

``srht_encode_batch`` - encode with one independent draw per (client, chunk).
``srht_decode_sum``   - y_c = sum_i G_i^T z_ic in one launch.
``srht_gram_apply``   - matrix-free S v = sum_i G_i^T G_i v (CG inner apply).

On TPU the Pallas kernel is used (compiled); elsewhere the same kernel body
runs in interpret mode, or the pure-jnp oracle for tiny shapes where the
interpreter overhead dominates. The oracle (kernels/ref.py) is the
correctness contract; tests assert allclose across shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .fwht import fwht_pallas
from ..obs import record_dispatch as _record_dispatch

# interpret-mode execution is pure-python per grid step; for the small chunk
# sizes used on CPU the vectorised oracle is much faster. The Pallas path is
# still exercised (interpret=True) by tests and by `use_pallas="force"`.
_PALLAS_MIN_ELEMS = 1 << 22


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _should_use_pallas(n_elems: int, use_pallas: str | bool) -> tuple[bool, bool]:
    """-> (use_kernel, interpret)"""
    if use_pallas == "force":
        return True, not _on_tpu()
    if use_pallas == "never" or use_pallas is False:
        return False, False
    if _on_tpu():
        return True, False
    return n_elems >= _PALLAS_MIN_ELEMS, True


def _dispatch(op: str, n_elems: int, use_pallas: str | bool) -> tuple[bool, bool]:
    """``_should_use_pallas`` + one telemetry count per decision.

    The decision is a Python static, so under jit it records at trace time —
    i.e. once per compilation, which is exactly the granularity at which the
    route is actually chosen."""
    use, interp = _should_use_pallas(n_elems, use_pallas)
    _record_dispatch(op, use, interp)
    return use, interp


def fwht(x: jnp.ndarray, *, scale: float = 1.0, use_pallas: str | bool = "auto") -> jnp.ndarray:
    """``scale * H_d @ x`` along the last axis; x: (..., d)."""
    d = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d)
    use, interp = _dispatch("fwht", x2.size, use_pallas)
    if use:
        out = fwht_pallas(x2, with_signs=False, scale=scale, interpret=interp)
    else:
        out = _ref.fwht_ref(x2)
        if scale != 1.0:
            out = out * jnp.asarray(scale, out.dtype)
    return out.reshape(*lead, d)


def srht_encode(
    x: jnp.ndarray,
    signs: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    use_pallas: str | bool = "auto",
) -> jnp.ndarray:
    """Fused SRHT encode ``G x = (1/sqrt(d)) (H (signs * x))[rows]``.

    x: (..., d); signs: (d,); rows: (k,) int32. -> (..., k)
    The sign-multiply and 1/sqrt(d) scale are fused into the kernel; the
    row-gather stays in XLA (cheap, k << d).
    """
    d = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d)
    use, interp = _dispatch("srht_encode", x2.size, use_pallas)
    inv = 1.0 / math.sqrt(d)
    if use:
        t = fwht_pallas(x2, signs, with_signs=True, scale=inv, interpret=interp)
    else:
        t = _ref.fwht_ref(x2 * signs) * jnp.asarray(inv, x2.dtype)
    out = jnp.take(t, rows, axis=-1)
    return out.reshape(*lead, rows.shape[0])


def srht_decode(
    u: jnp.ndarray,
    signs: jnp.ndarray,
    rows: jnp.ndarray,
    d: int,
    *,
    use_pallas: str | bool = "auto",
) -> jnp.ndarray:
    """SRHT adjoint ``G^T u = (1/sqrt(d)) signs * (H scatter_rows(u))``.

    u: (..., k) -> (..., d). H is symmetric so H^T == H.
    """
    k = u.shape[-1]
    lead = u.shape[:-1]
    u2 = u.reshape(-1, k)
    full = jnp.zeros((u2.shape[0], d), u2.dtype)
    full = full.at[:, rows].set(u2)
    use, interp = _dispatch("srht_decode", full.size, use_pallas)
    inv = 1.0 / math.sqrt(d)
    if use:
        t = fwht_pallas(full, with_signs=False, scale=inv, interpret=interp)
        out = t * signs
    else:
        out = _ref.fwht_ref(full) * (signs * jnp.asarray(inv, u2.dtype))
    return out.reshape(*lead, d)


def flash_attention(q, k, v, *, rep: int, window: int = 0, q_offset: int = 0,
                    q_tile: int = 128, kv_tile: int = 128,
                    use_pallas: str | bool = "auto"):
    """Tiled flash attention; q (N_q, Sq, dh), k/v (N_kv, Sk, dh).

    Pallas kernel on TPU; oracle elsewhere (interpret mode is exercised by
    tests — running it for real workloads on CPU is interpreter-bound).
    """
    from .flash_attention import flash_attention_pallas

    use, interp = _dispatch("flash_attention", q.size, use_pallas)
    if use_pallas == "force" or (use and _on_tpu()):
        return flash_attention_pallas(
            q, k, v, rep=rep, window=window, q_offset=q_offset,
            q_tile=q_tile, kv_tile=kv_tile, interpret=interp,
        )
    return _ref.flash_attention_ref(q, k, v, rep=rep, window=window, q_offset=q_offset)


def srht_encode_batch(
    x: jnp.ndarray,
    signs: jnp.ndarray,
    rows: jnp.ndarray,
    *,
    use_pallas: str | bool = "auto",
) -> jnp.ndarray:
    """Fused batched SRHT encode with PER-ROW draws.

    ``out[..r..] = (1/sqrt(d)) (H (signs[..r..] * x[..r..]))[rows[..r..]]``

    x, signs: (..., d); rows: (..., k) int32 — leading dims aligned, one
    independent draw per leading index (the non-shared-randomness encode,
    batched over clients x chunks). Contrast `srht_encode`, which shares one
    (signs, rows) draw across the whole batch.
    """
    from .srht_fused import fwht_rowsigns_pallas

    d = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d)
    s2 = jnp.broadcast_to(signs, x.shape).reshape(-1, d)
    use, interp = _dispatch("srht_encode_batch", x2.size, use_pallas)
    inv = 1.0 / math.sqrt(d)
    if use:
        t = fwht_rowsigns_pallas(x2, s2, sign_pre=True, scale=inv, interpret=interp)
    else:
        t = _ref.fwht_rowsigns_ref(x2, s2, sign_pre=True, scale=inv)
    t = t.reshape(*lead, d)
    return jnp.take_along_axis(t, rows, axis=-1)


def srht_decode_sum(
    z: jnp.ndarray,
    signs: jnp.ndarray,
    rows: jnp.ndarray,
    d: int,
    *,
    use_pallas: str | bool = "auto",
) -> jnp.ndarray:
    """Fused client-summed SRHT adjoint ``y_c = sum_i G_i^T z_ic``.

    z: (n, C, k); signs: (n, C|1, d); rows: (n, C|1, k) — the middle axis is 1
    when clients share one draw across chunks (shared_randomness). -> (C, d)

    The scatter to full width stays in XLA (cheap, k << d, fuses with the
    payload unpack); the FWHT + sign/scale + scatter-add over clients is one
    Pallas launch batched over (clients x chunks).
    """
    from .srht_fused import srht_decode_sum_pallas

    full = _ref.srht_scatter_ref(z, rows, d)  # (n, C, d)
    use, interp = _dispatch("srht_decode_sum", full.size, use_pallas)
    inv = 1.0 / math.sqrt(d)
    if use:
        return srht_decode_sum_pallas(full, signs, scale=inv, interpret=interp)
    out = _ref.fwht_rowsigns_ref(full, signs, sign_post=True, scale=inv)
    return jnp.sum(out, axis=0)


def srht_gram_apply(
    v: jnp.ndarray,
    signs: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    use_pallas: str | bool = "auto",
) -> jnp.ndarray:
    """Fused matrix-free ``S v = sum_i G_i^T G_i v`` for SRHT maps.

    v: (C, d); signs, mask: (n, C|1, d) with mask the 0/1 row indicator of
    each draw. Two FWHTs with a coordinate mask between them — the CG inner
    apply of the fused decode (docs/DESIGN.md §3.5). -> (C, d)
    """
    from .srht_fused import srht_gram_apply_pallas

    n = signs.shape[0]
    d = v.shape[-1]
    use, interp = _dispatch("srht_gram_apply", n * v.shape[0] * d, use_pallas)
    if use:
        return srht_gram_apply_pallas(v, signs, mask, scale=1.0 / d, interpret=interp)
    return _ref.srht_gram_apply_ref(v, signs, mask)


# ------------------------------------------------- very-sparse projection ops
# The SparseProj codec's hot ops (core/estimators/sparse_proj.py). These are
# gather/scatter bound with O(k * nnz) work per chunk — there is no FWHT-like
# dense structure for a Pallas kernel to fuse, and XLA already fuses the
# gather+reduce / scatter-add, so the dispatch is pinned to the XLA path
# (use_pallas="never"). They still route through ``_dispatch`` so the kernel
# telemetry (repro.obs) records the decision at trace time like every other
# op, and a future Pallas lowering slots in without touching callers.


def sparse_proj_encode(x: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray) -> jnp.ndarray:
    """Very-sparse projection encode ``G x``, G rows = nnz signed entries of
    magnitude 1/sqrt(nnz) at key-derived columns (unit-norm rows).

    x: (..., d); signs, cols: (..., k, nnz) broadcast-aligned. -> (..., k)
    O(k * nnz) flops per vector vs the SRHT's O(d log d).
    """
    nnz = cols.shape[-1]
    _dispatch("sparse_proj_encode", x.size, "never")
    out = _ref.sparse_encode_ref(x, signs, cols)
    return out * jnp.asarray(1.0 / math.sqrt(nnz), out.dtype)


def sparse_proj_adjoint(
    z: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray, d: int
) -> jnp.ndarray:
    """Sparse adjoint ``G^T z`` per leading index (no client sum — the decode
    keeps the per-client scatters for its pooled R-hat statistic).

    z: (..., k); signs, cols: (..., k, nnz) broadcast-aligned (the decode
    passes (n, C, k) values with (n, C|1, k, nnz) draws). -> (..., d)
    """
    _dispatch("sparse_proj_adjoint", z.size, "never")
    out = _ref.sparse_scatter_add_ref(z, signs, cols, d)
    nnz = cols.shape[-1]
    return out * jnp.asarray(1.0 / math.sqrt(nnz), out.dtype)


def sparse_proj_gram_apply(
    v: jnp.ndarray, signs: jnp.ndarray, cols: jnp.ndarray
) -> jnp.ndarray:
    """Matrix-free ``S v = sum_i G_i^T G_i v`` for sparse maps — the CG inner
    apply of the SparseProj resolvent decode.

    v: (C, d); signs, cols: (n, C|1, k, nnz). -> (C, d)
    """
    n = signs.shape[0]
    _dispatch("sparse_proj_gram_apply", n * v.size, "never")
    nnz = cols.shape[-1]
    out = _ref.sparse_gram_apply_ref(v, signs, cols)
    return out * jnp.asarray(1.0 / nnz, out.dtype)


def srht_rows_matrix(signs: jnp.ndarray, rows: jnp.ndarray, d: int) -> jnp.ndarray:
    """Materialise G = (1/sqrt(d)) E H D as a (k, d) matrix.

    Used by the Gram-trick decode (docs/DESIGN.md §3.3) where A = stack(G_i) is
    fed to MXU matmuls. Row r of E H D is H[rows[r], :] * signs.
    """
    h = jnp.asarray(_ref.hadamard_matrix(d), jnp.float32)
    return (h[rows, :] * signs[None, :]) * (1.0 / np.sqrt(d))
