"""Parse compiled HLO text for collective traffic + FLOP/byte statistics.

cost_analysis() has no collective-bytes entry, so we regex the
post-partitioning HLO: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we take the result shape and the
replica-group size and charge ring-algorithm bytes-per-device:

    all-gather:         out * (g-1)/g        (each device receives the rest)
    all-reduce:         2 * size * (g-1)/g   (reduce-scatter + all-gather)
    reduce-scatter:     in * (g-1)/g  = out * (g-1)
    all-to-all:         size * (g-1)/g
    collective-permute: size
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.:  %all-gather.3 = bf16[2,128,64]{2,1,0} all-gather(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[\s(.]"
)
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_RE2.search(line)
    if m:  # iota form [num_groups,group_size]
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, default_group: int = 2) -> dict:
    """-> {"per_op": {op: {count, result_bytes, wire_bytes}}, totals...}.

    wire_bytes = estimated bytes crossing links per device for one execution.
    """
    per_op = defaultdict(lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
    per_group = defaultdict(lambda: {"count": 0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not any(f" {c}" in stripped or stripped.startswith(c) for c in _COLLECTIVES):
            continue
        if "-start" in stripped.split("=")[0]:
            pass  # async start carries the shape; -done lines skipped below
        if re.match(r"^\s*%?\S*-done", stripped):
            continue
        rhs = stripped.split("=", 1)[-1].lstrip()
        if rhs.startswith("("):
            # tuple-shaped result, e.g. (bf16[..], bf16[..]) all-reduce(...)
            opname = next((c for c in _COLLECTIVES if f" {c}(" in stripped), None)
            if opname is None:
                continue
            lhs = stripped.split(opname)[0]
            shapes = _TUPLE_SHAPE_RE.findall(lhs.split("=")[-1])
            if not shapes:
                continue
            bytes_ = sum(_shape_bytes(dt, dm) for dt, dm in shapes)
            op = opname
        else:
            m = _OP_RE.search(stripped)
            if not m:
                continue
            dt, dims, op = m.group(1), m.group(2), m.group(3)
            bytes_ = _shape_bytes(dt, dims)
        g = _group_size(stripped, default_group)
        frac = (g - 1) / g if g > 1 else 0.0
        if op == "all-gather":
            wire = bytes_ * frac
        elif op == "all-reduce":
            wire = 2 * bytes_ * frac
        elif op == "reduce-scatter":
            wire = bytes_ * (g - 1)
        elif op == "all-to-all":
            wire = bytes_ * frac
        else:  # collective-permute
            wire = bytes_
        d = per_op[op]
        d["count"] += 1
        d["result_bytes"] += bytes_
        d["wire_bytes"] += wire
        g2 = per_group[g]  # mesh-axis attribution: group size identifies the axis
        g2["count"] += 1
        g2["wire_bytes"] += wire
    totals = {
        "count": sum(v["count"] for v in per_op.values()),
        "result_bytes": sum(v["result_bytes"] for v in per_op.values()),
        "wire_bytes": sum(v["wire_bytes"] for v in per_op.values()),
    }
    return {"per_op": dict(per_op), "per_group_size": dict(per_group), "totals": totals}
