"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --preset tiny --steps 200 --estimator rand_proj_spatial --clients 4

- --preset tiny|small|full scales the arch config (tiny/small run on CPU;
  full is the real config for cluster meshes).
- The DME estimator compresses the cross-client gradient mean exactly as in
  the multi-pod deployment (client axis = leading batch dim; on a real mesh
  the axis shards over 'pod').
- Fault tolerance: checkpoints every --ckpt-every steps; restart the same
  command line and it resumes; --inject-failures demonstrates recovery.
"""
from __future__ import annotations

import argparse
import functools
import os

import jax

from .. import configs
from ..core import codec
from ..data import SyntheticLM
from ..models import init_params
from ..optim import AdamW
from ..train import make_train_step
from ..train.train_step import init_train_state
from ..train.supervisor import FaultPlan, Supervisor


def preset_config(arch: str, preset: str):
    cfg = configs.get_config(arch)
    if preset == "full":
        return cfg
    if preset == "tiny":
        return configs.reduce_for_smoke(cfg)
    # "small": ~100M-class model of the same family
    kw = dict(d_model=512, vocab_size=8192, n_blocks=min(cfg.n_blocks, 8),
              vocab_pad_multiple=64, remat="none", dtype="float32")
    if cfg.n_heads:
        kw.update(n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4), d_head=64)
    if cfg.d_ff:
        kw.update(d_ff=2048)
    if cfg.n_experts:
        kw.update(n_experts=min(cfg.n_experts, 8), d_ff_expert=512)
    if cfg.mamba_d_inner:
        kw.update(mamba_d_inner=1024, d_state=64)
    return cfg.replace(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m", choices=list(configs.ARCHS))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4, help="DME clients (0 = no compression)")
    ap.add_argument("--estimator", default="rand_proj_spatial")
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--d-block", type=int, default=1024)
    ap.add_argument("--transform", default="avg")
    ap.add_argument("--ef", action="store_true", help="error feedback (top_k/wangni)")
    ap.add_argument("--dme-ownership", type=int, default=0,
                    help="owner shards for the sharded server decode "
                         "(docs/DESIGN.md §10); 0 = replicated decode")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="layer-pipeline the block stack over this many "
                         "devices (GPipe over a 'pipe' mesh axis); 0 = off")
    ap.add_argument("--pipeline-microbatches", type=int, default=0,
                    help="microbatch count for --pipeline-stages "
                         "(default: the stage count)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--non-iid", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failures", default="", help="comma steps, e.g. 30,80")
    ap.add_argument("--resize", default="", help="step:new_n, e.g. 100:3")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    print(f"[train] {cfg.name} preset={args.preset}: {cfg.n_params()/1e6:.1f}M params, "
          f"{args.clients or 1} clients, estimator="
          f"{args.estimator if args.clients else 'none (uncompressed)'}")
    optimizer = AdamW(lr=args.lr, warmup_steps=20)

    dme = None
    if args.clients:
        dme = codec.build(args.estimator, k=args.k, d_block=args.d_block,
                          transform=args.transform, ef=args.ef)

    pipe_mesh = None
    if args.pipeline_stages:
        pipe_mesh = jax.make_mesh((args.pipeline_stages,), ("pipe",))

    def make_step(n_clients):
        spec = dme
        step = make_train_step(cfg, optimizer, dme_spec=spec if n_clients else None,
                               dme_ownership=args.dme_ownership,
                               mesh=pipe_mesh,
                               pipeline_stages=args.pipeline_stages,
                               pipeline_microbatches=args.pipeline_microbatches)
        return jax.jit(step, donate_argnums=(0, 1))

    def make_data(n_clients):
        data = SyntheticLM(
            vocab_size=cfg.vocab_size, seq_len=args.seq, batch=args.batch,
            n_clients=n_clients, seed=args.seed, non_iid=args.non_iid,
            embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0,
        )
        return functools.partial(_data_at, data)

    def _data_at(data, step):
        return data.batch_at(step)

    def init_state():
        params = init_params(cfg, jax.random.key(args.seed))
        return params, init_train_state(cfg, optimizer, params, dme, args.clients)

    plan = FaultPlan(
        fail_at_steps=tuple(int(s) for s in args.inject_failures.split(",") if s),
        resize_at={int(kv.split(":")[0]): int(kv.split(":")[1])
                   for kv in args.resize.split(",") if kv} or None,
    )
    sup = Supervisor(
        make_step=make_step, make_data=make_data, init_state=init_state,
        ckpt_dir=os.path.join(args.ckpt_dir, f"{cfg.name}_{args.preset}"),
        n_clients=args.clients, ckpt_every=args.ckpt_every,
    )
    params, state, history = sup.run(args.steps, fault_plan=plan)
    if history:
        first, last = history[0][1], history[-1][1]
        print(f"[train] loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    return history


if __name__ == "__main__":
    main()
