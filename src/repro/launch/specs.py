"""input_specs(): ShapeDtypeStruct stand-ins (weak-type-correct, shardable,
zero allocation) for every (arch x input-shape) dry-run cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..dist import sharding as shard_lib
from ..models import transformer
from ..models.common import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _sds(shape, dtype, ns: NamedSharding):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=ns)


def supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    info = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode skipped (docs/DESIGN.md §6)"
    if info["kind"] == "train" and cfg.input_mode == "embeddings":
        # VLM backbone trains on embeddings; still supported (stub frontend)
        return True, ""
    return True, ""


def batch_specs(cfg: ModelConfig, shape_name: str, mesh, *, n_clients: int = 0):
    """Training batch SDS. n_clients > 0 adds the leading DME client dim."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    dp = shard_lib.dp_axes(mesh)
    if n_clients:
        lead = (n_clients, b // n_clients)
        tok_spec = P("pod", "data", *([None] * (2 if cfg.input_mode == "embeddings" else 1)))
        lab_spec = P("pod", "data", None)
    else:
        lead = (b,)
        tok_spec = P(dp, *([None] * (2 if cfg.input_mode == "embeddings" else 1)))
        lab_spec = P(dp, None)
    if cfg.input_mode == "embeddings":
        inputs = _sds(lead + (s, cfg.d_model), jnp.bfloat16, NamedSharding(mesh, tok_spec))
    else:
        inputs = _sds(lead + (s,), jnp.int32, NamedSharding(mesh, tok_spec))
    labels = _sds(lead + (s,), jnp.int32, NamedSharding(mesh, lab_spec))
    return {"inputs": inputs, "labels": labels}


def decode_specs(cfg: ModelConfig, shape_name: str, mesh):
    """(cache, tokens, positions) SDS for decode; (cache, tokens) for prefill."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    seq_shard = shape_name == "long_500k"
    cache_abs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, jnp.bfloat16)
    )
    cache_sh = shard_lib.cache_shardings(cfg, mesh, cache_abs, seq_shard=seq_shard)
    cache = jax.tree.map(
        lambda a, ns: _sds(a.shape, a.dtype, ns), cache_abs, cache_sh
    )
    dp = shard_lib.dp_axes(mesh)
    bspec = P(None) if seq_shard else P(dp)
    if info["kind"] == "prefill":
        if cfg.input_mode == "embeddings":
            tokens = _sds((b, s, cfg.d_model), jnp.bfloat16, NamedSharding(mesh, P(dp, None, None)))
        else:
            tokens = _sds((b, s), jnp.int32, NamedSharding(mesh, P(dp, None)))
        return cache, tokens, None
    if cfg.input_mode == "embeddings":
        tokens = _sds((b, 1, cfg.d_model), jnp.bfloat16,
                      NamedSharding(mesh, P(*bspec, None, None)))
    else:
        tokens = _sds((b, 1), jnp.int32, NamedSharding(mesh, P(*bspec, None)))
    positions = _sds((b, 1), jnp.int32, NamedSharding(mesh, P(*bspec, None)))
    return cache, tokens, positions


def params_specs(cfg: ModelConfig, mesh, *, model_pref=shard_lib.MODEL_PREF,
                 fsdp: bool = True):
    abs_p = transformer.abstract_params(cfg)
    shards = shard_lib.param_shardings(cfg, mesh, model_pref=model_pref, fsdp=fsdp)
    return jax.tree.map(lambda a, ns: _sds(a.shape, a.dtype, ns), abs_p, shards)


def opt_state_specs(optimizer, params_sds):
    """eval_shape the optimizer init; moment trees inherit param shardings."""
    abs_state = jax.eval_shape(optimizer.init, params_sds)

    def attach(path, leaf):
        # mu/nu mirror params: reuse the param leaf sharding at the same subpath
        if path and getattr(path[0], "key", None) in ("mu", "nu", "m"):
            sub = params_sds
            for p in path[1:]:
                key = getattr(p, "key", getattr(p, "idx", None))
                sub = sub[key]
            return _sds(leaf.shape, leaf.dtype, sub.sharding)
        return leaf  # scalars (step): let jit default to replicated

    return jax.tree_util.tree_map_with_path(attach, abs_state)
