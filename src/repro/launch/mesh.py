"""Production mesh builders (a FUNCTION, not a module constant: importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants for the roofline model (docs/EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
