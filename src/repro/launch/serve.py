"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --preset tiny \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import init_cache, init_params
from ..train import make_decode_step, make_prefill_step
from .train import preset_config


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=list(configs.ARCHS))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{cfg.name} is an embeddings-input stub; serve tokens archs")
    print(f"[serve] {cfg.name} preset={args.preset}: {cfg.n_params()/1e6:.1f}M params")
    params = init_params(cfg, jax.random.key(args.seed))
    seq_cap = args.prompt_len + args.gen
    cache = init_cache(cfg, args.batch, seq_cap)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    prompts = jax.random.randint(
        jax.random.key(args.seed + 1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    logits, cache = prefill(params, cache, prompts)
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch, 1), args.prompt_len + i, jnp.int32)
        nxt, _, cache = decode(params, cache, tok, pos)
        tok = nxt[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"[serve] decoded {args.gen - 1} steps in {t_decode:.2f}s "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print(f"[serve] sample continuation (request 0): {gen[0].tolist()}")
    return gen


if __name__ == "__main__":
    main()
