import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). Everything below is ordinary code.

# Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh)
# cell on placeholder host devices; record memory/cost/collective analysis.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
#         --shape train_4k --mesh both --out results/dryrun
#
# Cells are cached as JSON (skip if present unless --force): the full 40-cell
# sweep is resumable and composes with benchmarks/roofline.py, which renders
# docs/EXPERIMENTS.md tables from the same JSON.

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from .. import configs
from ..dist import sharding as shard_lib
from ..models import transformer
from ..optim import AdamW
from ..train import make_train_step
from ..core import codec
from . import hlo_stats, specs
from .mesh import make_production_mesh

RESULT_DIR_DEFAULT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _cell_fn_and_args(cfg, shape_name, mesh, dme: str, knobs: dict):
    """Build (fn, example_args) for one cell."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..models import act_sharding

    kind = specs.SHAPES[shape_name]["kind"]
    # canonical activation layout: batch over the DP axes (§Perf: prevents
    # GSPMD from propagating a batch-replicated layout through the stack).
    if knobs.get("act_constraint", True) and kind != "decode":
        dp = shard_lib.dp_axes(mesh)
        act_sharding.set_constraint(NamedSharding(mesh, P(dp, None, None)))
    else:
        act_sharding.set_constraint(None)
    model_pref = (
        shard_lib.MODEL_PREF_EP if knobs.get("ep_first") else shard_lib.MODEL_PREF
    )
    params = specs.params_specs(
        cfg, mesh, model_pref=model_pref, fsdp=not knobs.get("no_fsdp", False)
    )
    if kind == "train":
        opt = AdamW(lr=3e-4)
        state = {"opt": specs.opt_state_specs(opt, params)}
        if dme == "off":
            step_fn = make_train_step(cfg, opt)
            batch = specs.batch_specs(cfg, shape_name, mesh)
        else:
            client_axes = ("pod", "data") if dme == "poddata" else (dme,)
            n_clients = 1
            for a in client_axes:
                n_clients *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
            spec = codec.build(
                knobs.get("estimator", "rand_proj_spatial"),
                k=knobs.get("k", 64),
                d_block=knobs.get("d_block", 1024),
                transform=knobs.get("transform", "avg"),
                shared_randomness=not knobs.get("per_chunk", False),
                decode_method=knobs.get("decode_method", "gram"),
                use_pallas="never",  # XLA path in the lowered graph off-TPU
            )
            step_fn = make_train_step(
                cfg, opt, dme_spec=spec, mesh=mesh, client_axes=client_axes,
                dme_impl=knobs.get("dme_impl", "auto"),
            )
            batch = specs.batch_specs(cfg, shape_name, mesh, n_clients=n_clients)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return step_fn, (params, state, batch, step)

    cache, tokens, positions = specs.decode_specs(cfg, shape_name, mesh)
    if kind == "prefill":
        fn = lambda p, c, t: transformer.prefill(p, cfg, c, t)
        return fn, (params, cache, tokens)
    fn = lambda p, c, t, q: transformer.decode_step(p, cfg, c, t, q)
    return fn, (params, cache, tokens, positions)


def run_cell(arch: str, shape_name: str, multi_pod: bool, dme: str, knobs=None) -> dict:
    knobs = knobs or {}
    t0 = time.time()
    cfg = configs.get_config(arch)
    cfg_over = {k: knobs[k] for k in
                ("n_blocks", "force_unroll", "remat", "attn_kv_block", "dtype",
                 "mamba_chunk", "capacity_factor", "mamba_split_proj",
                 "param_dtype", "attn_impl", "gqa_repeat_kv")
                if k in knobs}
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "dme": dme,
        "knobs": knobs,
        "n_params": cfg.n_params(),
        "n_params_active": cfg.n_params_active(),
    }
    ok, why = specs.supported(cfg, shape_name)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        if "mesh_shape" in knobs:
            # ablation meshes, e.g. [2, 256, 1] = DP-dominant 2-pod (§Perf H-c.4)
            mesh = jax.make_mesh(tuple(knobs["mesh_shape"]), ("pod", "data", "model"))
            rec["mesh"] = "x".join(str(s) for s in knobs["mesh_shape"])
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        n_devices = mesh.devices.size
        fn, args = _cell_fn_and_args(cfg, shape_name, mesh, dme, knobs)
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for field in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                if hasattr(ma, field):
                    mem[field] = int(getattr(ma, field))
        except Exception as e:  # CPU backend may not support it
            mem["error"] = repr(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            for key in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
                if key in ca:
                    cost[key] = float(ca[key])
        except Exception as e:
            cost["error"] = repr(e)

        text = compiled.as_text()
        coll = hlo_stats.collective_stats(text, default_group=2 if multi_pod else 16)
        rec.update(
            status="ok",
            n_devices=n_devices,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory=mem,
            cost=cost,
            collectives=coll,
            hlo_bytes=len(text),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def cell_path(out_dir, arch, shape_name, mesh_name, dme, tag="") -> str:
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}__{dme}{suffix}.json")


def run_calibration(arch, shape_name, multi_pod, dme, knobs=None) -> dict:
    """Two-point block-count calibration: compile at n_blocks in {1, 2} with
    all loops unrolled (no HLO whiles -> exact cost_analysis + collective
    parse), then affine-extrapolate f(nb) = a + b*nb to the full depth.
    Needed because XLA cost analysis counts while bodies ONCE (docs/EXPERIMENTS.md
    §Dry-run, methodology)."""
    knobs = dict(knobs or {})
    cfg = configs.get_config(arch)
    points = {}
    for nb in (1, 2):
        k = dict(knobs)
        k.update(n_blocks=nb, force_unroll=True)
        points[nb] = run_cell(arch, shape_name, multi_pod, dme, k)
        if points[nb]["status"] != "ok":
            return {"status": "error", "points": points, "arch": arch,
                    "shape": shape_name, "dme": dme,
                    "mesh": "pod2x16x16" if multi_pod else "pod16x16"}

    def fit(get):
        y1, y2 = get(points[1]), get(points[2])
        b = y2 - y1
        a = y1 - b
        return a, b

    full_nb = cfg.n_blocks
    out = {
        "status": "ok",
        "arch": arch, "shape": shape_name, "dme": dme,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "knobs": knobs, "n_blocks_full": full_nb,
        "points": points,
    }
    for name, get in [
        ("flops", lambda r: r["cost"].get("flops", 0.0)),
        ("bytes", lambda r: r["cost"].get("bytes accessed", 0.0)),
        ("wire_bytes", lambda r: r["collectives"]["totals"]["wire_bytes"]),
        ("coll_result_bytes", lambda r: r["collectives"]["totals"]["result_bytes"]),
    ]:
        a, b = fit(get)
        out[f"{name}_full"] = a + b * full_nb
        out[f"{name}_fit"] = {"a": a, "b": b}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(specs.SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--dme", default="default",
                    help="off|pod|data|poddata|default (default: pod on multi-pod "
                         "train cells, off elsewhere)")
    ap.add_argument("--out", default=RESULT_DIR_DEFAULT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for experiment variants")
    ap.add_argument("--knobs", default="{}", help="JSON perf knobs")
    ap.add_argument("--calibrate", action="store_true",
                    help="two-point unrolled cost calibration instead of full compile")
    args = ap.parse_args()

    archs = list(configs.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(specs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    knobs = json.loads(args.knobs)

    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                if args.dme == "default":
                    kind = specs.SHAPES[shape_name]["kind"]
                    dme = "pod" if (multi_pod and kind == "train") else "off"
                else:
                    dme = args.dme
                mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
                tag = ("calib" + args.tag) if args.calibrate else args.tag
                path = cell_path(args.out, arch, shape_name, mesh_name, dme, tag)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {path}")
                    continue
                print(f"[run] {arch} x {shape_name} x {mesh_name} dme={dme} "
                      f"{'CALIB' if args.calibrate else ''}...", flush=True)
                if args.calibrate:
                    cfg0 = configs.get_config(arch)
                    ok, why = specs.supported(cfg0, shape_name)
                    if not ok:
                        rec = {"status": "skipped", "reason": why, "arch": arch,
                               "shape": shape_name, "mesh": mesh_name, "dme": dme}
                    else:
                        rec = run_calibration(arch, shape_name, multi_pod, dme, knobs)
                else:
                    rec = run_cell(arch, shape_name, multi_pod, dme, knobs)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                if args.calibrate:
                    extra = f"flops_full={rec.get('flops_full'):.3e}" if status == "ok" else rec.get("reason", "error")
                else:
                    extra = (
                        f"compile={rec.get('compile_s')}s flops={rec.get('cost', {}).get('flops')}"
                        if status == "ok" else rec.get("reason") or rec.get("error")
                    )
                print(f"[{status}] {arch} x {shape_name} x {mesh_name}: {extra}", flush=True)


if __name__ == "__main__":
    main()
