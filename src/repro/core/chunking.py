"""Blockwise application of DME estimators to framework-scale vectors.

The paper analyses a single d-dimensional vector; a model gradient has
d ~ 1e9. We flatten the gradient pytree, zero-pad to a multiple of
``d_block`` (a power of two, so SRHT applies per block), and run the
estimator vmapped/batched over chunks. All of the paper's per-vector
guarantees (unbiasedness, MSE) hold per chunk; MSE adds across chunks.
See docs/DESIGN.md §3.1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def num_chunks(d_flat: int, d_block: int) -> int:
    return -(-d_flat // d_block)


def chunk(x: jnp.ndarray, d_block: int) -> jnp.ndarray:
    """(d_flat,) -> (C, d_block), zero-padding the tail."""
    (d_flat,) = x.shape
    c = num_chunks(d_flat, d_block)
    pad = c * d_block - d_flat
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(c, d_block)


def unchunk(xc: jnp.ndarray, d_flat: int) -> jnp.ndarray:
    """(C, d_block) -> (d_flat,), dropping pad."""
    return xc.reshape(-1)[:d_flat]


def flatten_tree(tree):
    """pytree -> (flat (d,), unravel_fn). Thin wrapper for a stable import point."""
    flat, unravel = ravel_pytree(tree)
    return flat, unravel


def tree_chunk(tree, d_block: int):
    """pytree -> ((C, d_block) chunks, restore_fn)."""
    flat, unravel = ravel_pytree(tree)
    d_flat = flat.shape[0]
    xc = chunk(flat, d_block)

    def restore(xc_hat: jnp.ndarray):
        return unravel(unchunk(xc_hat, d_flat))

    return xc, restore
