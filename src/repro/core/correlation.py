"""Cross-client correlation measure R (paper Eq. 7) and helpers."""
from __future__ import annotations

import jax.numpy as jnp


def r_exact(xs: jnp.ndarray) -> jnp.ndarray:
    """R = sum_{i != l} <x_i, x_l> / sum_i ||x_i||^2 for xs (n, ..., d).

    Chunk axes are flattened into the inner product (R of the full vectors).
    """
    n = xs.shape[0]
    flat = xs.reshape(n, -1).astype(jnp.float32)
    total = jnp.sum(flat, axis=0)
    sq = jnp.sum(flat * flat)
    return (jnp.dot(total, total) - sq) / (sq + 1e-12)


def mse(x_hat: jnp.ndarray, x_bar: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((x_hat.astype(jnp.float32) - x_bar.astype(jnp.float32)) ** 2)
