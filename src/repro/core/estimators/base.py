"""Codec registry + the deprecated flat ``EstimatorSpec`` shim.

The estimator API lives in ``repro.core.codec`` now: a typed ``Payload``
container, per-estimator config dataclasses, and a composable ``Pipeline``
of stages (sparsifier / quantizer / error feedback / temporal). This module
keeps two things:

1. **The registry** — each codec implementation module registers a ``Codec``
   (pure ``encode`` / ``decode`` / ``self_decode`` functions) under its
   name. Implementations consume the typed sparsifier configs (they read
   ``spec.k`` / ``spec.d_block`` / ...), and the shared-randomness key
   derivation helpers (``client_key`` / ``chunk_key``) stay here: the round
   key is shared by clients and server, per-client randomness is
   ``fold_in(key, client_id)``, so indices/signs/seeds are never transmitted
   (docs/DESIGN.md §3.6).

2. **The deprecation shim** — ``EstimatorSpec`` still constructs (emitting
   one ``DeprecationWarning`` per process) and every module-level function
   (``encode`` / ``decode`` / ``encode_all`` / ``mean_estimate`` /
   ``self_decode``) accepts an ``EstimatorSpec``, a sparsifier config, or a
   ``Pipeline``, normalising through ``codec.as_pipeline``. Existing call
   sites keep working unchanged during migration; new code should construct
   pipelines directly (see docs/DESIGN.md §3.0 for the field-by-field
   migration table).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class EstimatorSpec:
    """DEPRECATED flat estimator config — use ``repro.core.codec`` instead.

    Construction converts 1:1 to a ``Pipeline`` via ``codec.as_pipeline``:
    ``name``/``k``/``d_block``/... pick the sparsifier config,
    ``payload_dtype`` becomes a quantizer stage, ``ef`` becomes an
    ``ErrorFeedback`` stage. Kept so pre-migration call sites (and the
    examples that demonstrate the shim) run unmodified.
    """

    name: str = "rand_proj_spatial"
    k: int = 64                      # per-client per-chunk budget
    d_block: int = 1024              # chunk size (power of two)
    transform: str = "avg"           # spatial family: one|max|avg|opt
    r_value: float | None = None     # oracle R for transform="opt", r_mode="fixed"
    r_mode: str = "fixed"            # fixed | est (online R-hat from payloads)
    shared_randomness: bool = True   # same G_i for all chunks of a round (fast path)
    decode_method: str = "auto"      # auto | fused | gram | direct
    projection: str = "srht"         # srht | subsample (Lemma 4.1) | gauss
    beta_trials: int | None = None   # None -> adaptive default
    use_pallas: str = "auto"         # auto | force | never
    wangni_capacity: float = 1.5     # -> codec.Wangni(capacity=...)
    induced_topk_frac: float = 0.5   # -> codec.Induced(topk_frac=...)
    ef: bool = False                 # -> codec.ErrorFeedback() stage
    payload_dtype: str = "float32"   # -> codec.Bf16Quant() / codec.Int8Quant()

    def __post_init__(self):
        _warn_deprecated_once()

    def replace(self, **kw) -> "EstimatorSpec":
        return dataclasses.replace(self, **kw)


_DEPRECATION_MSG = (
    "EstimatorSpec is deprecated; compose a repro.core.codec Pipeline instead "
    "(codec.build(name, **old_kwargs) is the drop-in constructor; see "
    "docs/DESIGN.md §3.0 for the migration table)"
)
_warned_deprecated = False


def _warn_deprecated_once() -> None:
    global _warned_deprecated
    if _warned_deprecated:
        return
    # Latch only AFTER the warn call returns: under -W error::DeprecationWarning
    # (the CI `deprecations` job) warn() raises and the latch stays unset, so
    # EVERY stray first-party construction errors no matter what ran before it
    # — the latch cannot be consumed by an earlier allowlisted test.
    # stacklevel: user code -> generated __init__ -> __post_init__ -> here
    warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=4)
    _warned_deprecated = True


def _reset_deprecation_warning_for_tests() -> None:
    global _warned_deprecated
    _warned_deprecated = False


@dataclasses.dataclass(frozen=True)
class Codec:
    encode: Callable[..., Any]
    decode: Callable[..., Any]
    # self_decode(spec, key, client_id, arrays) -> (C, d): the client's own
    # reconstruction of what the server received from it — drives error
    # feedback, temporal memories, and the FL server's correlation tracker.
    self_decode: Callable[..., Any] | None = None


_REGISTRY: dict[str, Codec] = {}


def register(name: str, codec: Codec) -> None:
    _REGISTRY[name] = codec


def get(name: str) -> Codec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def client_key(key, client_id):
    return jax.random.fold_in(key, client_id)


def chunk_key(ckey, chunk_id):
    return jax.random.fold_in(ckey, chunk_id)


# --------------------------------------------------------------------------
# Functional convenience API. Accepts EstimatorSpec | sparsifier config |
# Pipeline; thin delegation to repro.core.codec (imported lazily — codec
# imports this module for the registry).


def _pipe(spec):
    from .. import codec

    return codec.as_pipeline(spec)


def encode(spec, key, client_id, x_cd, side_info=None):
    return _pipe(spec).encode(key, client_id, x_cd, side_info=side_info)[0]


def decode(spec, key, payloads, n: int, client_ids=None, side_info=None,
           chunk_offset=0):
    return _pipe(spec).decode(
        key, payloads, n, client_ids=client_ids, side_info=side_info,
        chunk_offset=chunk_offset,
    )


def self_decode(spec, key, client_id, payload):
    return _pipe(spec).self_decode(key, client_id, payload)


def encode_all(spec, key, xs, client_ids=None, side_info=None):
    """xs: (n, C, d) -> stacked payloads (leading n)."""
    payloads, _ = _pipe(spec).encode_all(
        key, xs, client_ids=client_ids, side_info=side_info
    )
    return payloads


def mean_estimate(spec, key, xs, client_ids=None, side_info=None):
    """One-shot DME: xs (n, C, d) client chunks -> (C, d) mean estimate."""
    return _pipe(spec).mean_estimate(
        key, xs, client_ids=client_ids, side_info=side_info
    )
