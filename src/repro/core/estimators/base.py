"""Codec registry + shared key derivation + the functional estimator API.

The estimator API lives in ``repro.core.codec``: a typed ``Payload``
container, per-estimator config dataclasses, and a composable ``Pipeline``
of stages (sparsifier / quantizer / error feedback / temporal). This module
keeps the pieces under it:

- **The registry** — each codec implementation module registers a ``Codec``
  (pure ``encode`` / ``decode`` / ``self_decode`` functions) under its
  name. Implementations consume the typed sparsifier configs (they read
  ``spec.k`` / ``spec.d_block`` / ...), and the shared-randomness key
  derivation helpers (``client_key`` / ``chunk_key``) stay here: the round
  key is shared by clients and server, per-client randomness is
  ``fold_in(key, client_id)``, so indices/signs/seeds are never transmitted
  (docs/DESIGN.md §3.6).

- **The functional wrappers** — ``encode`` / ``decode`` / ``encode_all`` /
  ``mean_estimate`` / ``self_decode`` accept a ``Pipeline`` or a bare
  sparsifier config (normalised via ``codec.as_pipeline``) for one-shot use
  without threading pipeline state.

The deprecated flat ``EstimatorSpec`` that used to live here is GONE (its
one-process-warning shim ran for two release cycles); ``codec.build(name,
**old_kwargs)`` remains as the keyword-compatible constructor — see the
README migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class Codec:
    encode: Callable[..., Any]
    decode: Callable[..., Any]
    # self_decode(spec, key, client_id, arrays) -> (C, d): the client's own
    # reconstruction of what the server received from it — drives error
    # feedback, temporal memories, and the FL server's correlation tracker.
    self_decode: Callable[..., Any] | None = None


_REGISTRY: dict[str, Codec] = {}


def register(name: str, codec: Codec) -> None:
    _REGISTRY[name] = codec


def get(name: str) -> Codec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def client_key(key, client_id):
    return jax.random.fold_in(key, client_id)


def chunk_key(ckey, chunk_id):
    return jax.random.fold_in(ckey, chunk_id)


# --------------------------------------------------------------------------
# Functional convenience API. Accepts a sparsifier config | Pipeline; thin
# delegation to repro.core.codec (imported lazily — codec imports this
# module for the registry).


def _pipe(spec):
    from .. import codec

    return codec.as_pipeline(spec)


def encode(spec, key, client_id, x_cd, side_info=None):
    return _pipe(spec).encode(key, client_id, x_cd, side_info=side_info)[0]


def decode(spec, key, payloads, n: int, client_ids=None, side_info=None,
           chunk_offset=0):
    return _pipe(spec).decode(
        key, payloads, n, client_ids=client_ids, side_info=side_info,
        chunk_offset=chunk_offset,
    )


def self_decode(spec, key, client_id, payload):
    return _pipe(spec).self_decode(key, client_id, payload)


def encode_all(spec, key, xs, client_ids=None, side_info=None):
    """xs: (n, C, d) -> stacked payloads (leading n)."""
    payloads, _ = _pipe(spec).encode_all(
        key, xs, client_ids=client_ids, side_info=side_info
    )
    return payloads


def mean_estimate(spec, key, xs, client_ids=None, side_info=None):
    """One-shot DME: xs (n, C, d) client chunks -> (C, d) mean estimate."""
    return _pipe(spec).mean_estimate(
        key, xs, client_ids=client_ids, side_info=side_info
    )
