"""Estimator spec + registry.

Every estimator is a pair of pure functions

    encode(spec, key, client_id, x_cd)               : (C, d) -> payload pytree
    decode(spec, key, payloads, n, client_ids=None)  : stacked payloads
                                                       (leading n) -> (C, d)

- ``key`` is the *round* key, shared by every client and the server
  (deterministic shared randomness: per-client randomness is re-derived as
  fold_in(key, client_id), so index/sign/seed information is never
  transmitted — see docs/DESIGN.md §3.6).
- Payloads are pytrees of arrays with identical structure across clients, so
  they stack/all-gather cleanly.
- ``client_ids`` decouples key derivation from payload position: when only a
  subset of clients participates in a round (partial participation, straggler
  drops — repro.fl), the server decodes the survivors' payloads with their
  *actual* ids so the re-derived randomness matches what each client used,
  and normalises by the actual participant count n.
- ``side_info`` is the temporal-correlation hook (docs/DESIGN.md §8.2, after
  Rand-k-Temporal): clients encode x_i - side, the server adds side back to
  the decoded delta mean. Any unbiased codec stays unbiased and its MSE
  scales with ||x_i - side||^2 instead of ||x_i||^2.
- ``mean_estimate`` is the one-shot convenience used by benchmarks/tests and
  by the paper-style DME drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EstimatorSpec:
    name: str = "rand_proj_spatial"
    k: int = 64                      # per-client per-chunk budget
    d_block: int = 1024              # chunk size (power of two)
    transform: str = "avg"           # spatial family: one|max|avg|opt
    r_value: float | None = None     # oracle R for transform="opt", r_mode="fixed"
    r_mode: str = "fixed"            # fixed | est (online R-hat from payloads)
    shared_randomness: bool = True   # same G_i for all chunks of a round (fast path)
    decode_method: str = "gram"      # gram | direct (paper-literal d x d eigh)
    projection: str = "srht"         # srht | subsample (Lemma 4.1) | gauss
    beta_trials: int | None = None   # None -> adaptive default
    use_pallas: str = "auto"         # auto | force | never
    wangni_capacity: float = 1.5     # payload capacity multiplier (see wangni.py)
    induced_topk_frac: float = 0.5   # budget split for the induced compressor
    ef: bool = False                 # error-feedback residual (train-loop level)
    # payload quantization (paper §7 future work: sparsification x quantization):
    # float32 | bfloat16 | int8. int8 uses per-chunk scales + STOCHASTIC
    # rounding, so the composed estimator stays unbiased (tested).
    payload_dtype: str = "float32"

    def replace(self, **kw) -> "EstimatorSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Codec:
    encode: Callable[..., Any]
    decode: Callable[..., Any]
    # self_decode(spec, key, client_id, payload) -> (C, d): the client's own
    # reconstruction of what the server received from it — used by error
    # feedback (residual = input - self_decode). Only meaningful for (semi-)
    # biased codecs (top_k, wangni, induced).
    self_decode: Callable[..., Any] | None = None
    bits_per_client: Callable[[EstimatorSpec, int], int] | None = None


_REGISTRY: dict[str, Codec] = {}


def register(name: str, codec: Codec) -> None:
    _REGISTRY[name] = codec


def get(name: str) -> Codec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def client_key(key, client_id):
    return jax.random.fold_in(key, client_id)


def chunk_key(ckey, chunk_id):
    return jax.random.fold_in(ckey, chunk_id)


_VAL_KEYS = ("vals", "top_vals", "rand_vals")
_VAL_SALT = {"vals": 101, "top_vals": 211, "rand_vals": 307}  # stable fold_in tags


def _quantize_payload(spec: EstimatorSpec, key, payload: dict) -> dict:
    if spec.payload_dtype == "float32":
        return payload
    out = {}
    for name, v in payload.items():
        if name not in _VAL_KEYS:
            out[name] = v
            continue
        if spec.payload_dtype == "bfloat16":
            out[name] = v.astype(jnp.bfloat16)
        elif spec.payload_dtype == "int8":
            scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0 + 1e-12
            u = jax.random.uniform(jax.random.fold_in(key, _VAL_SALT[name]), v.shape)
            q = jnp.floor(v / scale + u)  # stochastic rounding: E[q*scale] = v
            out[name] = jnp.clip(q, -128, 127).astype(jnp.int8)
            out[name + "_scale"] = scale.astype(jnp.float32)
        else:
            raise ValueError(spec.payload_dtype)
    return out


def _dequantize_payload(spec: EstimatorSpec, payload: dict) -> dict:
    if spec.payload_dtype == "float32":
        return payload
    out = {}
    for name, v in payload.items():
        if name.endswith("_scale"):
            continue
        if name in _VAL_KEYS:
            if spec.payload_dtype == "bfloat16":
                out[name] = v.astype(jnp.float32)
            else:
                out[name] = v.astype(jnp.float32) * payload[name + "_scale"]
        else:
            out[name] = v
    return out


def encode(spec: EstimatorSpec, key, client_id, x_cd: jnp.ndarray, side_info=None):
    if side_info is not None:
        x_cd = x_cd - side_info
    payload = get(spec.name).encode(spec, key, client_id, x_cd)
    return _quantize_payload(spec, client_key(key, client_id), payload)


def decode(
    spec: EstimatorSpec, key, payloads, n: int, client_ids=None, side_info=None
) -> jnp.ndarray:
    out = get(spec.name).decode(
        spec, key, _dequantize_payload(spec, payloads), n, client_ids=client_ids
    )
    if side_info is not None:
        out = out + side_info
    return out


def self_decode(spec: EstimatorSpec, key, client_id, payload) -> jnp.ndarray:
    codec = get(spec.name)
    if codec.self_decode is None:
        raise ValueError(f"estimator {spec.name!r} does not support error feedback")
    return codec.self_decode(spec, key, client_id, _dequantize_payload(spec, payload))


def encode_all(spec: EstimatorSpec, key, xs: jnp.ndarray, client_ids=None,
               side_info=None):
    """xs: (n, C, d) -> stacked payloads (leading n).

    ``client_ids`` (n,) overrides the default 0..n-1 identity assignment —
    used when xs holds only the participating subset of a larger cohort.
    """
    n = xs.shape[0]
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
    return jax.vmap(lambda i, x: encode(spec, key, i, x, side_info=side_info))(ids, xs)


def mean_estimate(spec: EstimatorSpec, key, xs: jnp.ndarray, client_ids=None,
                  side_info=None) -> jnp.ndarray:
    """One-shot DME: xs (n, C, d) client chunks -> (C, d) mean estimate."""
    n = xs.shape[0]
    payloads = encode_all(spec, key, xs, client_ids=client_ids, side_info=side_info)
    return decode(spec, key, payloads, n, client_ids=client_ids, side_info=side_info)
