"""Estimator registry. Importing this package registers all codecs.

The estimator *API* moved to ``repro.core.codec`` (composable pipelines with
typed payloads); this package keeps the registered codec implementations and
the functional wrappers.
"""
from . import (  # noqa: F401
    identity,
    induced,
    rand_k,
    rand_k_spatial,
    rand_proj_spatial,
    sparse_proj,
    top_k,
    wangni,
)
from .base import (  # noqa: F401
    Codec,
    decode,
    encode,
    encode_all,
    get,
    mean_estimate,
    names,
    register,
    self_decode,
)
