"""No-compression baseline: clients send full vectors, server averages."""
from __future__ import annotations

import jax.numpy as jnp

from . import base


def encode(spec, key, client_id, x_cd):
    return {"vals": x_cd}


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    return jnp.mean(payloads["vals"], axis=0)


def self_decode(spec, key, client_id, payload):
    return payload["vals"]


base.register(
    "identity", base.Codec(encode=encode, decode=decode, self_decode=self_decode)
)
