"""No-compression baseline: clients send full vectors, server averages."""
from __future__ import annotations

import jax.numpy as jnp

from . import base


def encode(spec, key, client_id, x_cd):
    return {"vals": x_cd}


def decode(spec, key, payloads, n):
    return jnp.mean(payloads["vals"], axis=0)


base.register("identity", base.Codec(encode=encode, decode=decode))
