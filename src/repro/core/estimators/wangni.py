"""Non-uniform gradient sparsification (Wangni et al. 2018) — "Rand-k(Wangni)".

Adaptive unbiased sparsification: coordinate j is kept with probability p_j
and rescaled to x_j / p_j, with {p_j} minimising variance subject to
sum_j p_j = k. The optimal p_j = min(1, |x_j| / tau) with tau the water-level
solving sum_j min(1, |x_j|/tau) = k; we solve it with a fixed number of
saturation iterations (the paper's iterative greedy algorithm, jit-friendly).

Payload-shape note: Bernoulli selection has variable size; for fixed-shape
collectives we allocate capacity ceil(capacity * k) and drop overflow
(lowest-|value| survivors dropped first). Overflow is rare for the optimal
p (E[count] = k, var <= k); drops introduce a tiny bias which we accept and
document — the estimator is a baseline from the paper's comparison set.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import base, top_k

_ITERS = 12


def probabilities(x_d: jnp.ndarray, k: int) -> jnp.ndarray:
    """Optimal inclusion probabilities for one chunk (d,)."""
    a = jnp.abs(x_d) + 1e-30
    sat = jnp.zeros_like(a, dtype=bool)

    def body(_, sat):
        denom = jnp.sum(jnp.where(sat, 0.0, a))
        budget = k - jnp.sum(sat)
        tau = denom / jnp.maximum(budget, 1e-30)
        return sat | (a >= tau)

    sat = jax.lax.fori_loop(0, _ITERS, body, sat)
    denom = jnp.sum(jnp.where(sat, 0.0, a))
    budget = jnp.maximum(k - jnp.sum(sat), 0.0)
    p = jnp.where(sat, 1.0, a * budget / jnp.maximum(denom, 1e-30))
    return jnp.clip(p, 0.0, 1.0)


def capacity(spec) -> int:
    return int(math.ceil(spec.capacity * spec.k))


def encode(spec, key, client_id, x_cd):
    ckey = base.client_key(key, client_id)
    cap = capacity(spec)

    def one(kk, x):
        p = probabilities(x, spec.k)
        keep = jax.random.bernoulli(kk, p)
        scaled = jnp.where(keep, x / jnp.maximum(p, 1e-30), 0.0)
        # fixed-capacity packing: keep the largest-|scaled| selected coords
        score = jnp.where(keep, jnp.abs(scaled), -1.0)
        _, idx = jax.lax.top_k(score, cap)
        vals = jnp.where(jnp.take(keep, idx), jnp.take(scaled, idx), 0.0)
        return vals, idx.astype(jnp.int32)

    c = x_cd.shape[0]
    keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
    vals, idx = jax.vmap(one)(keys, x_cd)
    return {"vals": vals, "idx": idx}


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    # encode keys chunks by position, but the chosen indices travel in the
    # payload — the decode is position-free, so owner-sliced decodes work.
    return top_k.scatter_mean(payloads["vals"], payloads["idx"], n, spec.d_block)


def self_decode(spec, key, client_id, payload):
    return top_k.scatter_mean(payload["vals"][None], payload["idx"][None], 1, spec.d_block)


base.register("wangni", base.Codec(encode=encode, decode=decode, self_decode=self_decode))
