"""Rand-k-Spatial family (Jhunjhunwala et al. 2021) — paper Eq. 2/3.

Encoding is identical to Rand-k. The server scales coordinate j by
beta / T(M_j), where M_j is the number of clients that sent coordinate j and
T(m) = 1 + rho (m-1) interpolates with the degree of correlation.
beta is exact (binomial expectation, see core/beta.py), in-graph and
differentiable in rho so the online R-hat mode composes.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import beta as beta_lib
from .. import transforms
from . import base, rand_k


def encode(spec, key, client_id, x_cd):
    payload = rand_k.encode(spec, key, client_id, x_cd)
    if spec.r_mode == "est":
        payload["norm_sq"] = jnp.sum(x_cd.astype(jnp.float32) ** 2, axis=-1)
    return payload


def _rho(spec, n, payloads, s, m):
    if spec.r_mode != "est":
        return transforms.rho_for(spec.transform, n, spec.r_value)
    # Online R-hat from unbiased per-client decodes (docs/DESIGN.md §5):
    #   sum_{i != l} <xh_i, xh_l> = ||sum_i xh_i||^2 - sum_i ||xh_i||^2,
    # with xh_i = (d/k) scatter(vals_i) and exact ||x_i||^2 side info.
    d, k = spec.d_block, spec.k
    scale = d / k
    sum_dec_sq = jnp.sum((scale * s) ** 2)
    # ||xh_i||^2 = scale^2 * ||vals_i||^2 (scatter preserves norms)
    per_client_sq = scale**2 * jnp.sum(payloads["vals"].astype(jnp.float32) ** 2)
    norm_sq_total = jnp.sum(payloads["norm_sq"]) + 1e-12
    r_hat = (sum_dec_sq - per_client_sq) / norm_sq_total
    return transforms.clip_rho(r_hat / (n - 1.0), n)


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    s, m = rand_k.scatter_sum_and_counts(spec, key, payloads["vals"], n,
                                         client_ids, chunk_offset)
    rho = _rho(spec, n, payloads, s, m)
    b = beta_lib.rand_k_spatial_beta(n, spec.k, spec.d_block, rho)
    t = transforms.t_apply(m, rho)
    scaled = jnp.where(m > 0, s / jnp.where(m > 0, t, 1.0), 0.0)
    return (b / n) * scaled


# Encoding is Rand-k's, so the unbiased per-client reconstruction is too.
CODEC = base.Codec(encode=encode, decode=decode, self_decode=rand_k.self_decode)
base.register("rand_k_spatial", CODEC)
