"""Rand-k sparsification (Konecny & Richtarik 2018) — the paper's baseline.

Each client sends k of its d coordinates, chosen uniformly without
replacement; indices are re-derived from the shared round key, so only the k
values travel. Decode: x_hat = (1/n)(d/k) sum_i scatter(vals_i).
MSE (paper Eq. 1): (1/n^2)(d/k - 1) sum_i ||x_i||^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base


def _indices(spec, key, client_id, n_chunks: int,
             chunk_offset=0):
    """(C, k) int32 coordinate choices for one client.

    ``chunk_offset`` is the GLOBAL position of the first chunk: per-chunk
    randomness (shared_randomness=False) is keyed by global chunk id, so a
    chunk-slice decode (the sharded server decode, dist.sharding chunk
    ownership) re-derives exactly the indices of a full-array decode.
    """
    ckey = base.client_key(key, client_id)
    d, k = spec.d_block, spec.k
    if spec.shared_randomness:
        idx = jax.random.permutation(ckey, d)[:k]
        return jnp.broadcast_to(idx, (n_chunks, k))
    positions = chunk_offset + jnp.arange(n_chunks)
    keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, positions)
    return jax.vmap(lambda kk: jax.random.permutation(kk, d)[:k])(keys)


def _budget_offsets(budgets):
    offs = [0]
    for b in budgets:
        offs.append(offs[-1] + b)
    return offs


def _budgeted_indices(spec, key, client_id, n_chunks: int):
    """Per-chunk index arrays ``[(k_0,), ..., (k_{C-1},)]`` for adaptive
    ``chunk_budgets`` — chunk c takes the first k_c entries of the (shared or
    chunk-keyed) permutation, so the draw at budget k_c is exactly the
    uniform-budget draw truncated/extended (same permutation prefix)."""
    budgets = spec.chunk_budgets
    if len(budgets) != n_chunks:
        raise ValueError(
            f"chunk_budgets has {len(budgets)} entries but the vector has "
            f"{n_chunks} chunks"
        )
    ckey = base.client_key(key, client_id)
    d = spec.d_block
    if spec.shared_randomness:
        perm = jax.random.permutation(ckey, d)
        return [perm[: budgets[ci]] for ci in range(n_chunks)]
    return [
        jax.random.permutation(base.chunk_key(ckey, ci), d)[: budgets[ci]]
        for ci in range(n_chunks)
    ]


def _budgeted_scatter(spec, key, vals_flat, ids):
    """(n, sum k_c) flat values -> (n, C, d) per-client unbiased estimates,
    each chunk scaled by its OWN d/k_c."""
    budgets = spec.chunk_budgets
    c = len(budgets)
    offs = _budget_offsets(budgets)
    d = spec.d_block

    def one(client_id, v):
        idxs = _budgeted_indices(spec, key, client_id, c)
        rows = [
            (d / budgets[ci])
            * jnp.zeros((d,), v.dtype).at[idxs[ci]].add(v[offs[ci]: offs[ci + 1]])
            for ci in range(c)
        ]
        return jnp.stack(rows)

    return jax.vmap(one)(ids, vals_flat)


def encode(spec, key, client_id, x_cd):
    c = x_cd.shape[0]
    if getattr(spec, "chunk_budgets", None) is not None:
        idxs = _budgeted_indices(spec, key, client_id, c)
        return {"vals": jnp.concatenate(
            [x_cd[ci, idxs[ci]] for ci in range(c)]
        )}
    idx = _indices(spec, key, client_id, c)
    vals = jnp.take_along_axis(x_cd, idx, axis=-1)
    return {"vals": vals}


def scatter_sum_and_counts(spec, key, vals, n, client_ids=None, chunk_offset=0):
    """Common Rand-k / Rand-k-Spatial decode plumbing.

    vals: (n, C, k) -> (sum (C, d), counts (C, d)) of scattered payloads.
    ``client_ids`` overrides the 0..n-1 id assignment (partial participation);
    ``chunk_offset`` is the global position of vals' first chunk (owner-sliced
    decode) — the scatter itself is per-chunk, so rows are independent.
    """
    c = vals.shape[1]
    d = spec.d_block
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)

    def one(client_id, v):
        idx = _indices(spec, key, client_id, c, chunk_offset)
        s = jnp.zeros((c, d), v.dtype).at[jnp.arange(c)[:, None], idx].add(v)
        m = jnp.zeros((c, d), jnp.float32).at[jnp.arange(c)[:, None], idx].add(1.0)
        return s, m

    ss, ms = jax.vmap(one)(ids, vals)
    return ss.sum(0), ms.sum(0)


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    if getattr(spec, "chunk_budgets", None) is not None:
        if chunk_offset:
            raise ValueError(
                "adaptive chunk_budgets decode is not shardable "
                "(chunk_offset must be 0)"
            )
        ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
        return _budgeted_scatter(spec, key, payloads["vals"], ids).sum(0) / n
    s, _ = scatter_sum_and_counts(spec, key, payloads["vals"], n, client_ids,
                                  chunk_offset)
    return (spec.d_block / (spec.k * n)) * s


def self_decode(spec, key, client_id, payload):
    """Unbiased per-client reconstruction (d/k) scatter(vals): what the server
    attributes to this client. Drives error feedback and the FL server's
    online correlation tracker (repro.fl.server)."""
    vals = payload["vals"]
    if getattr(spec, "chunk_budgets", None) is not None:
        ids = jnp.asarray(client_id)[None]
        return _budgeted_scatter(spec, key, vals[None], ids)[0]
    c = vals.shape[0]
    idx = _indices(spec, key, client_id, c)
    s = jnp.zeros((c, spec.d_block), vals.dtype)
    s = s.at[jnp.arange(c)[:, None], idx].add(vals)
    return (spec.d_block / spec.k) * s


CODEC = base.Codec(encode=encode, decode=decode, self_decode=self_decode)
base.register("rand_k", CODEC)
