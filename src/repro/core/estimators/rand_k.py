"""Rand-k sparsification (Konecny & Richtarik 2018) — the paper's baseline.

Each client sends k of its d coordinates, chosen uniformly without
replacement; indices are re-derived from the shared round key, so only the k
values travel. Decode: x_hat = (1/n)(d/k) sum_i scatter(vals_i).
MSE (paper Eq. 1): (1/n^2)(d/k - 1) sum_i ||x_i||^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base


def _indices(spec, key, client_id, n_chunks: int,
             chunk_offset=0):
    """(C, k) int32 coordinate choices for one client.

    ``chunk_offset`` is the GLOBAL position of the first chunk: per-chunk
    randomness (shared_randomness=False) is keyed by global chunk id, so a
    chunk-slice decode (the sharded server decode, dist.sharding chunk
    ownership) re-derives exactly the indices of a full-array decode.
    """
    ckey = base.client_key(key, client_id)
    d, k = spec.d_block, spec.k
    if spec.shared_randomness:
        idx = jax.random.permutation(ckey, d)[:k]
        return jnp.broadcast_to(idx, (n_chunks, k))
    positions = chunk_offset + jnp.arange(n_chunks)
    keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, positions)
    return jax.vmap(lambda kk: jax.random.permutation(kk, d)[:k])(keys)


def encode(spec, key, client_id, x_cd):
    c = x_cd.shape[0]
    idx = _indices(spec, key, client_id, c)
    vals = jnp.take_along_axis(x_cd, idx, axis=-1)
    return {"vals": vals}


def scatter_sum_and_counts(spec, key, vals, n, client_ids=None, chunk_offset=0):
    """Common Rand-k / Rand-k-Spatial decode plumbing.

    vals: (n, C, k) -> (sum (C, d), counts (C, d)) of scattered payloads.
    ``client_ids`` overrides the 0..n-1 id assignment (partial participation);
    ``chunk_offset`` is the global position of vals' first chunk (owner-sliced
    decode) — the scatter itself is per-chunk, so rows are independent.
    """
    c = vals.shape[1]
    d = spec.d_block
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)

    def one(client_id, v):
        idx = _indices(spec, key, client_id, c, chunk_offset)
        s = jnp.zeros((c, d), v.dtype).at[jnp.arange(c)[:, None], idx].add(v)
        m = jnp.zeros((c, d), jnp.float32).at[jnp.arange(c)[:, None], idx].add(1.0)
        return s, m

    ss, ms = jax.vmap(one)(ids, vals)
    return ss.sum(0), ms.sum(0)


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    s, _ = scatter_sum_and_counts(spec, key, payloads["vals"], n, client_ids,
                                  chunk_offset)
    return (spec.d_block / (spec.k * n)) * s


def self_decode(spec, key, client_id, payload):
    """Unbiased per-client reconstruction (d/k) scatter(vals): what the server
    attributes to this client. Drives error feedback and the FL server's
    online correlation tracker (repro.fl.server)."""
    vals = payload["vals"]
    c = vals.shape[0]
    idx = _indices(spec, key, client_id, c)
    s = jnp.zeros((c, spec.d_block), vals.dtype)
    s = s.at[jnp.arange(c)[:, None], idx].add(vals)
    return (spec.d_block / spec.k) * s


CODEC = base.Codec(encode=encode, decode=decode, self_decode=self_decode)
base.register("rand_k", CODEC)
