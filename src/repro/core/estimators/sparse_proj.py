"""SparseProj: very-sparse random projection with the correlation-aware
Gram-resolvent decode (the cheap-encode member of the Rand-Proj-Spatial
family — paper §4 framework, Achlioptas 2003 / Li et al. 2006 maps).

Encode (client i):   z_i = G_i x_i, each of G_i's k rows holding ``nnz``
key-derived columns with Rademacher signs and magnitude 1/sqrt(nnz) —
unit-norm rows, E[G^T G] = (k/d) I, exactly the family convention the SRHT
maps satisfy, at O(k nnz) = O(k d / s) encode flops instead of O(d log d).

Decode (server):     x_hat = (beta_eps/n) (T(S) + eps I)^{-1} sum_i G_i^T z_i,
T(lambda) = 1 - rho + rho lambda, solved matrix-free by the SAME batched
frozen-chunk CG as the fused SRHT path (``rand_proj_spatial.
_cg_resolvent_solve`` — per-chunk reductions, converged chunks frozen), so an
owner's chunk-slice decode is bitwise identical to the same rows of the
monolithic decode. beta is calibrated from a Monte-Carlo eigenvalue bank of
the SPARSE ensemble (``beta.sparse_eig_bank``, keyed by density) through the
shared ridge-compensated ``beta_fn_from_bank`` — the signed-permutation
invariance argument of docs/DESIGN.md §3.4 applies verbatim to sparse maps,
so unbiasedness is exact, not approximate.

``r_mode="est"``: sparse rows OVERLAP across clients (G_i G_i^T != I_k), so
there is no exact per-chunk norm identity to shard the online R-hat on — the
statistic here uses the exact per-client adjoints and pools ALL chunks into
one scalar rho. That mode is decode-non-shardable by construction and
``Pipeline.non_shardable_stage`` declares it (the ownership gate rejects it
naming this stage); the fixed-transform modes shard bitwise.

Draws are keyed from the round key (client fold_in, then GLOBAL chunk
position when ``shared_randomness=False``), so the server re-derives every
projection and only the k values per chunk cross the wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...kernels import ops as kops
from ...obs import record_cg_iters, record_decode_route
from .. import beta as beta_lib
from .. import transforms
from . import base
from .rand_proj_spatial import _cg_resolvent_solve


def _client_draw(spec, ckey):
    """One (signs, cols) draw for a single client / single chunk.

    cols: (k, nnz) uniform columns per row, sampled WITH replacement (the
    classic very-sparse-projection draw): O(k nnz) random words, where a
    distinct-column draw costs a top_k over (k, d) bits — measured ~30x the
    entire encode at smoke sizes. Within-row duplicates merge by sign
    addition in the adjoint/Gram (scatter-ADD), and every moment argument
    below survives: sign independence kills the t != t' cross terms, so
    E[G^T G] = (k/d) I exactly, and the beta bank simulates THIS sampler.
    signs: (k, nnz) Rademacher. The 1/sqrt(nnz) magnitude is applied by the
    kernels-layer ops, not stored.
    """
    d, k, nnz = spec.d_block, spec.k, spec.nnz
    k1, k2 = jax.random.split(ckey)
    cols = jax.random.randint(k2, (k, nnz), 0, d)
    signs = jax.random.rademacher(k1, (k, nnz), jnp.float32)
    return {"cols": cols, "signs": signs}


def _draws(spec, key, n, c, client_ids, chunk_offset):
    """All (client x chunk) draws, stacked: leaves (n, 1, k, nnz) in
    shared_randomness mode, (n, C, k, nnz) otherwise. Per-chunk draws are
    keyed by GLOBAL chunk position (chunk_offset + local index), so an
    owner's slice decode re-derives the full decode's maps."""
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
    if spec.shared_randomness:
        draws = jax.vmap(lambda i: _client_draw(spec, base.client_key(key, i)))(ids)
        return jax.tree.map(lambda v: v[:, None], draws)
    chunk_ids = chunk_offset + jnp.arange(c)

    def one(i):
        ckey = base.client_key(key, i)
        return jax.vmap(lambda cid: _client_draw(spec, base.chunk_key(ckey, cid)))(
            chunk_ids
        )

    return jax.vmap(one)(ids)


def encode(spec, key, client_id, x_cd):
    ckey = base.client_key(key, client_id)
    c = x_cd.shape[0]
    if spec.shared_randomness:
        draw = _client_draw(spec, ckey)
        vals = kops.sparse_proj_encode(x_cd, draw["signs"], draw["cols"])
    else:
        keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
        draws = jax.vmap(lambda kk: _client_draw(spec, kk))(keys)
        vals = kops.sparse_proj_encode(x_cd, draws["signs"], draws["cols"])
    out = {"vals": vals}
    if spec.r_mode == "est":
        out["norm_sq"] = jnp.sum(x_cd.astype(jnp.float32) ** 2, axis=-1)
    return out


def _beta(spec, n, rho, eps):
    bank = beta_lib.sparse_eig_bank(
        n, spec.k, spec.d_block, spec.nnz, spec.beta_trials
    )
    fn = beta_lib.beta_fn_from_bank(bank, n, spec.d_block, eps=eps)
    if jnp.ndim(rho) == 0:
        return fn(rho)
    return jax.vmap(fn)(rho)


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    """Gram-resolvent decode, matrix-free over the sparse maps."""
    record_decode_route("sparse_proj", "resolvent")
    d, k = spec.d_block, spec.k
    vals = payloads["vals"].astype(jnp.float32)  # (n, C, k)
    norm_sq = payloads.get("norm_sq")            # (n, C) or None
    c = vals.shape[1]
    draws = _draws(spec, key, n, c, client_ids, chunk_offset)
    signs, cols = draws["signs"], draws["cols"]

    adj = kops.sparse_proj_adjoint(vals, signs, cols, d)  # (n, C, d)
    y = jnp.sum(adj, axis=0)                              # (C, d)

    if spec.r_mode == "est":
        # Pooled online R-hat from the EXACT per-client adjoints (sparse rows
        # overlap, so ||G_i^T z_i||^2 != ||z_i||^2 and the SRHT path's
        # per-chunk shortcut does not apply): one scalar rho per decode,
        # which is WHY this mode is decode-non-shardable (pipeline gate).
        sc = (d / k) ** 2
        tot = sc * jnp.sum(y * y)
        per = sc * jnp.sum(adj * adj)
        r_hat = (tot - per) / (jnp.sum(norm_sq) + 1e-12)
        rho = transforms.clip_rho(r_hat / (n - 1.0), n)
    else:
        rho = jnp.asarray(transforms.rho_for(spec.transform, n, spec.r_value))

    eps = spec.ridge

    def apply_s(v):
        return kops.sparse_proj_gram_apply(v, signs, cols)

    xh, cg_it = _cg_resolvent_solve(y, rho, eps, apply_s, spec.cg_iters)
    record_cg_iters(cg_it)  # eager runs sample; under jit it's a tracer -> dropped
    b = _beta(spec, n, rho, eps)
    scale = (b / n) if jnp.ndim(b) == 0 else (b / n)[:, None]
    return scale * xh


def self_decode(spec, key, client_id, payload):
    """Unbiased per-client reconstruction (d/k) G_i^T z_i.

    E[G^T G] = (k/d) I for the unit-row-norm sparse ensemble, so the family
    scale d/k makes this the client's unbiased contribution — online-R
    tracking (fl.server.measure_rho) and error feedback compose unchanged.
    """
    ckey = base.client_key(key, client_id)
    vals = payload["vals"].astype(jnp.float32)  # (C, k)
    c = vals.shape[0]
    if spec.shared_randomness:
        draw = _client_draw(spec, ckey)
        signs, cols = draw["signs"], draw["cols"]
    else:
        keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
        draws = jax.vmap(lambda kk: _client_draw(spec, kk))(keys)
        signs, cols = draws["signs"], draws["cols"]
    scale = spec.d_block / spec.k
    return scale * kops.sparse_proj_adjoint(vals, signs, cols, spec.d_block)


CODEC = base.Codec(encode=encode, decode=decode, self_decode=self_decode)
base.register("sparse_proj", CODEC)
