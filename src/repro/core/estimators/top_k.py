"""Top-k sparsification (Shi et al. 2019): largest-|x| coordinates.

Biased; pairs with an ErrorFeedback stage in the training loop. Indices are
data-dependent so they are transmitted (int32 per coordinate), unlike the
seed-derived Rand-k / SRHT payloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base


def encode(spec, key, client_id, x_cd):
    vals, idx = jax.lax.top_k(jnp.abs(x_cd), spec.k)
    vals = jnp.take_along_axis(x_cd, idx, axis=-1)
    return {"vals": vals, "idx": idx.astype(jnp.int32)}


def scatter_mean(vals, idx, n, d):
    c = vals.shape[1]

    def one(v, ix):
        return jnp.zeros((c, d), v.dtype).at[jnp.arange(c)[:, None], ix].add(v)

    return jax.vmap(one)(vals, idx).sum(0) / n


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    # indices travel in the payload, so the decode is chunk-position-free:
    # chunk_offset (owner-sliced decode) is accepted and ignored.
    return scatter_mean(payloads["vals"], payloads["idx"], n, spec.d_block)


def self_decode(spec, key, client_id, payload):
    return scatter_mean(payload["vals"][None], payload["idx"][None], 1, spec.d_block)


base.register("top_k", base.Codec(encode=encode, decode=decode, self_decode=self_decode))
