"""Rand-Proj-Spatial family estimator (paper Eq. 5) — the core contribution.

Encode (client i):   xh_i = G_i x_i,  G_i = (1/sqrt(d)) E_i H D_i   (SRHT, Eq. 6)
Decode (server):     x_hat = (beta/n) (T(S))^dagger sum_i G_i^T G_i x_i,
                     S = sum_i G_i^T G_i,  T applied to S's eigenvalues.

Three decode paths (tests assert they agree to float tolerance):

- ``direct``  — the paper-literal algorithm: materialise S (d x d), eigh,
  apply T to the spectrum. O(d^2 nk). Kept as the faithful oracle.
- ``gram``    — our TPU adaptation (docs/DESIGN.md §3.3): with A = [G_1; ...; G_n]
  (nk x d) and z = concat of received payloads, S = A^T A and

      x_hat = (beta/n) * A^T U diag(1_{l>0} / T(l)) U^T z,
      A A^T = U diag(l) U^T   (nk x nk Gram eigendecomposition)

  which is EXACT (y = A^T z lies in range(S)) and costs O((nk)^2 d) MXU
  matmuls + one small eigh — removing the paper's Limitation #1.
- ``fused``   — the kernel fast path (docs/DESIGN.md §3.5, docs/KERNELS.md),
  default via ``decode_method="auto"`` for srht/subsample projections. The
  family transform is AFFINE, T(lambda) = 1 - rho + rho*lambda, so applying
  (T(S))^dagger to y = sum_i G_i^T z_i (which lies in range(S)) is a linear
  resolvent solve, not a spectral one:

      ((1 - rho + eps) I + rho S) x = y,      x_hat = (beta_eps / n) x

  solved matrix-free by conjugate gradients, where every S v is ONE fused
  Pallas launch (two FWHTs with a coordinate mask between them, batched over
  clients x chunks — kernels/srht_fused.py). No A materialisation, no eigh.
  The ridge eps keeps the solve well-posed at rho = 1; unbiasedness stays
  EXACT because beta is recalibrated against T_eps = T + eps (see
  beta.beta_fn_from_bank). With projection="subsample" S is diagonal (the
  hit-counts), the solve is closed-form with eps = 0, and the fused path is
  Rand-k-Spatial (Lemma 4.1) without any linear algebra.

``shared_randomness=True`` uses one {G_i} draw for all chunks of a round, so
a single Gram eigendecomposition serves every chunk and the per-chunk work
is two matmuls. ``False`` is the paper-faithful independent-per-chunk mode
(vmapped) used by the fidelity benchmarks.

Projections: "srht" (the paper's choice), "subsample" (recovers
Rand-k-Spatial exactly — Lemma 4.1), "gauss" (comparison baseline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...kernels import ops as kops
from ...kernels import ref as kref
from ...obs import record_cg_iters, record_decode_route
from .. import beta as beta_lib
from .. import transforms
from . import base

_EPS = 1e-4
_CG_TOL = 1e-4  # relative residual target of the fused resolvent solve


def _client_draw(spec, ckey):
    """One (signs, rows) draw for a single client / single chunk."""
    d, k = spec.d_block, spec.k
    k1, k2 = jax.random.split(ckey)
    proj = getattr(spec, "projection", None) or "srht"
    if proj == "srht":
        signs = jax.random.rademacher(k1, (d,), jnp.float32)
        # Uniform k-subset via top_k over random bits: same law as
        # permutation(d)[:k] (rows stay distinct, as G_i G_i^T = I_k
        # requires) but ~6x cheaper — permutation dominates the whole
        # fused-decode walltime at fig5 scale otherwise.
        rows = jax.lax.top_k(jax.random.bits(k2, (d,), jnp.uint32), k)[1]
        return {"signs": signs, "rows": rows}
    if proj == "subsample":
        # derive rows exactly as rand_k._indices does (from the unsplit client
        # key) so Lemma 4.1 holds bit-for-bit against Rand-k-Spatial.
        rows = jax.random.permutation(ckey, d)[:k]
        return {"rows": rows}
    if proj == "gauss":
        g = jax.random.normal(k1, (k, d)) / jnp.sqrt(d)
        return {"g": g}
    raise ValueError(f"unknown projection {proj!r}")


def _apply_g(spec, draw, x_cd):
    """G x for a chunk batch: (C, d) -> (C, k)."""
    if "signs" in draw:
        return kops.srht_encode(x_cd, draw["signs"], draw["rows"], use_pallas=spec.use_pallas)
    if "g" in draw:
        return x_cd @ draw["g"].T
    return jnp.take(x_cd, draw["rows"], axis=-1)


def _g_matrix(spec, draw):
    """Materialise G (k, d) for the Gram/direct decode."""
    d = spec.d_block
    if "signs" in draw:
        return kops.srht_rows_matrix(draw["signs"], draw["rows"], d)
    if "g" in draw:
        return draw["g"]
    return jax.nn.one_hot(draw["rows"], d, dtype=jnp.float32)


def encode(spec, key, client_id, x_cd):
    ckey = base.client_key(key, client_id)
    c = x_cd.shape[0]
    if spec.shared_randomness:
        draw = _client_draw(spec, ckey)
        vals = _apply_g(spec, draw, x_cd)
    else:
        keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
        draws = jax.vmap(lambda kk: _client_draw(spec, kk))(keys)
        if "signs" in draws:
            # fused batched encode: per-chunk sign flip + FWHT in one pass
            # (kernels/srht_fused.py); the row gather stays in XLA.
            vals = kops.srht_encode_batch(
                x_cd, draws["signs"], draws["rows"], use_pallas=spec.use_pallas
            )
        elif "g" in draws:
            vals = jnp.einsum("ckd,cd->ck", draws["g"], x_cd)
        else:
            vals = jnp.take_along_axis(x_cd, draws["rows"], axis=-1)
    out = {"vals": vals}
    if spec.r_mode == "est":
        out["norm_sq"] = jnp.sum(x_cd.astype(jnp.float32) ** 2, axis=-1)
    return out


def _stack_a(spec, key, n, chunk_id=None, client_ids=None):
    """A = [G_1; ...; G_n] (nk, d) re-derived from the round key.

    ``client_ids`` selects which clients' maps to stack (participants)."""

    def one(i):
        ckey = base.client_key(key, i)
        if chunk_id is not None:
            ckey = base.chunk_key(ckey, chunk_id)
        return _g_matrix(spec, _client_draw(spec, ckey))

    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
    mats = jax.vmap(one)(ids)  # (n, k, d)
    return mats.reshape(n * spec.k, spec.d_block)


def _rho_hat(spec, n, z, gram, norm_sq):
    """Per-chunk online R-hat (docs/DESIGN.md §5). z: (C, n, k); gram: (nk, nk)."""
    d, k = spec.d_block, spec.k
    scale = d / k
    zf = z.reshape(z.shape[0], n * k)
    total_sq = scale**2 * jnp.einsum("cp,pq,cq->c", zf, gram, zf)
    g4 = gram.reshape(n, k, n, k)
    diag_blocks = g4[jnp.arange(n), :, jnp.arange(n), :]  # (n, k, k)
    per_client_sq = scale**2 * jnp.einsum("cnk,nkl,cnl->c", z, diag_blocks, z)
    r_hat = (total_sq - per_client_sq) / (jnp.sum(norm_sq, axis=0) + 1e-12)
    return transforms.clip_rho(r_hat / (n - 1.0), n)  # (C,)


def _spectral_weights(spec, n, lam, rho):
    """1_{l>0} / T(l) per eigenvalue; rho scalar or per-chunk (C,)."""
    mask = lam > _EPS * jnp.max(lam)
    if jnp.ndim(rho) == 0:
        t = transforms.t_apply(lam, rho)
        return jnp.where(mask, 1.0 / t, 0.0)
    t = transforms.t_apply(lam[None, :], rho[:, None])
    return jnp.where(mask[None, :], 1.0 / t, 0.0)  # (C, nk)


def _beta(spec, n, rho, eps: float = 0.0):
    if spec.projection == "subsample":
        # eigenvalues of S are the binomial hit-counts M_j: beta is exact
        # (Lemma 4.1: the estimator IS Rand-k-Spatial). The fused decode
        # solves the diagonal system exactly, so no ridge is involved.
        def fn(r):
            return beta_lib.rand_k_spatial_beta(n, spec.k, spec.d_block, r)
    else:
        bank = beta_lib.srht_eig_bank(
            n, spec.k, spec.d_block, spec.beta_trials, projection=spec.projection
        )
        fn = beta_lib.beta_fn_from_bank(bank, n, spec.d_block, eps=eps)
    if jnp.ndim(rho) == 0:
        return fn(rho)
    return jax.vmap(fn)(rho)


def _decode_one_gram(spec, n, a, z, norm_sq):
    """Gram-trick decode. a: (nk, d); z: (C, n, k) -> (C, d)."""
    gram = a @ a.T  # (nk, nk) — MXU
    lam, u = jnp.linalg.eigh(gram)
    if spec.r_mode == "est":
        rho = _rho_hat(spec, n, z, gram, norm_sq)
    else:
        rho = jnp.asarray(transforms.rho_for(spec.transform, n, spec.r_value))
    w = _spectral_weights(spec, n, lam, rho)  # (nk,) or (C, nk)
    b = _beta(spec, n, rho)  # scalar or (C,)
    zf = z.reshape(z.shape[0], n * spec.k)
    proj = (zf @ u) * (w if w.ndim == 2 else w[None, :])  # (C, nk)
    y = proj @ u.T  # (C, nk)
    xh = y @ a  # (C, d) — MXU
    scale = (b / n) if jnp.ndim(b) == 0 else (b / n)[:, None]
    return scale * xh


def _decode_one_direct(spec, n, a, z, norm_sq):
    """Paper-literal decode: eigh of S = A^T A (d x d). Oracle path."""
    s = a.T @ a
    lam, v = jnp.linalg.eigh(s)  # (d,), (d, d)
    gram = a @ a.T
    if spec.r_mode == "est":
        rho = _rho_hat(spec, n, z, gram, norm_sq)
    else:
        rho = jnp.asarray(transforms.rho_for(spec.transform, n, spec.r_value))
    mask = lam > _EPS * jnp.max(lam)
    if jnp.ndim(rho) == 0:
        w = jnp.where(mask, 1.0 / transforms.t_apply(lam, rho), 0.0)[None, :]
    else:
        w = jnp.where(
            mask[None, :], 1.0 / transforms.t_apply(lam[None, :], rho[:, None]), 0.0
        )
    b = _beta(spec, n, rho)
    zf = z.reshape(z.shape[0], n * spec.k)
    y = zf @ a  # (C, d): y_c = A^T z_c
    xh = ((y @ v) * w) @ v.T
    scale = (b / n) if jnp.ndim(b) == 0 else (b / n)[:, None]
    return scale * xh


def _fused_draws(spec, key, n, c, client_ids, chunk_offset):
    """All (client x chunk) draws, stacked for the batched kernels.

    Returns leaves of shape (n, 1, ...) in shared_randomness mode (one draw
    per client, broadcast over chunks) and (n, C, ...) otherwise. Chunk draws
    are keyed by GLOBAL chunk position (chunk_offset + local index), so an
    owner's slice decode re-derives the full decode's maps.
    """
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
    if spec.shared_randomness:
        draws = jax.vmap(lambda i: _client_draw(spec, base.client_key(key, i)))(ids)
        return jax.tree.map(lambda v: v[:, None], draws)
    chunk_ids = chunk_offset + jnp.arange(c)

    def one(i):
        ckey = base.client_key(key, i)
        return jax.vmap(lambda cid: _client_draw(spec, base.chunk_key(ckey, cid)))(
            chunk_ids
        )

    return jax.vmap(one)(ids)


def _cg_resolvent_solve(y, rho, eps, apply_s, iters):
    """Batched CG for ((1 - rho + eps) I + rho S) x = y, one system per chunk.

    All reductions are per-chunk (row-independent), and converged chunks are
    FROZEN via jnp.where — so decoding an owner's chunk slice is bitwise
    identical to slicing the monolithic decode, regardless of how many extra
    iterations the slowest chunk in the batch needs (the ownership-sharding
    contract, tests/test_ownership.py).

    y: (C, d); rho: scalar or (C,). Zero-payload chunks (y = 0, e.g. the
    padding added by collectives.sharded_decode) converge at iteration 0 and
    return exactly 0 — the alpha denominator is guarded so they cannot NaN.
    """
    c0 = 1.0 - rho + eps
    c1 = rho

    def col(v):
        return v if jnp.ndim(v) == 0 else v[:, None]

    def apply_m(v):
        return col(c0) * v + col(c1) * apply_s(v)

    ys = jnp.sum(y * y, axis=-1, keepdims=True)  # (C, 1)
    tol2 = (_CG_TOL * _CG_TOL) * ys
    x = jnp.zeros_like(y)
    done = ys <= tol2  # catches y == 0 exactly
    carry = (jnp.int32(0), x, y, y, ys, done)

    def cond(carry):
        it, _, _, _, _, done = carry
        return (it < iters) & ~jnp.all(done)

    def body(carry):
        it, x, r, p, rs, done = carry
        ap = apply_m(p)
        pap = jnp.sum(p * ap, axis=-1, keepdims=True)
        alpha = jnp.where(done, 0.0, rs / jnp.where(pap > 0, pap, 1.0))
        x2 = jnp.where(done, x, x + alpha * p)
        r2 = jnp.where(done, r, r - alpha * ap)
        rs2 = jnp.where(done, rs, jnp.sum(r2 * r2, axis=-1, keepdims=True))
        done2 = done | (rs2 <= tol2)
        bet = jnp.where(done2, 0.0, rs2 / jnp.where(rs > 0, rs, 1.0))
        p2 = jnp.where(done2, p, r2 + bet * p)
        return it + 1, x2, r2, p2, rs2, done2

    it, x, _, _, _, _ = jax.lax.while_loop(cond, body, carry)
    return x, it


def _decode_fused(spec, key, payloads, n, client_ids, chunk_offset):
    """Kernel fast-path decode: batched over (clients x chunks), no eigh.

    y = sum_i G_i^T z_i is one fused scatter-add launch; (T(S))^dagger y is a
    matrix-free resolvent solve (CG whose inner apply is one fused Gram
    launch), or a closed-form diagonal solve for projection="subsample".
    """
    d, k = spec.d_block, spec.k
    vals = payloads["vals"].astype(jnp.float32)  # (n, C, k)
    norm_sq = payloads.get("norm_sq")
    c = vals.shape[1]
    draws = _fused_draws(spec, key, n, c, client_ids, chunk_offset)
    rows = draws["rows"]  # (n, Cs, k), Cs in {1, C}
    signs = draws.get("signs")

    if signs is not None:
        y = kops.srht_decode_sum(vals, signs, rows, d, use_pallas=spec.use_pallas)
    else:
        y = jnp.sum(kref.srht_scatter_ref(vals, rows, d), axis=0)  # (C, d)

    if spec.r_mode == "est":
        # matrix-free R-hat (docs/DESIGN.md §5): z^T A A^T z = ||A^T z||^2 =
        # ||y||^2 and z_i^T G_i G_i^T z_i = ||z_i||^2 (G_i G_i^T = I_k exactly
        # for srht and subsample maps), so no Gram matrix is needed and the
        # statistic is per-chunk — it shards untouched across owners.
        sc = (d / k) ** 2
        tot = sc * jnp.sum(y * y, axis=-1)  # (C,)
        per = sc * jnp.sum(vals * vals, axis=(0, 2))  # (C,)
        r_hat = (tot - per) / (jnp.sum(norm_sq, axis=0) + 1e-12)
        rho = transforms.clip_rho(r_hat / (n - 1.0), n)  # (C,)
    else:
        rho = jnp.asarray(transforms.rho_for(spec.transform, n, spec.r_value))

    mask = kref.srht_scatter_ref(jnp.ones(rows.shape, jnp.float32), rows, d)

    if spec.projection == "subsample":
        # S = diag(hit counts): (T(S))^dagger is a closed-form elementwise
        # divide — the fused path IS Rand-k-Spatial (Lemma 4.1), eps = 0.
        hits = jnp.sum(mask, axis=0)  # (Cs, d)
        t = transforms.t_apply(hits, rho if jnp.ndim(rho) == 0 else rho[:, None])
        # explicit reciprocal-then-multiply: keeps the op sequence identical
        # across batch shapes (XLA may otherwise hoist broadcast divides),
        # which the ownership slice-parity contract relies on.
        xh = y * jnp.where(hits > 0, 1.0 / t, 0.0)
        b = _beta(spec, n, rho)
    else:
        eps = getattr(spec, "ridge", 1e-2)
        iters = getattr(spec, "cg_iters", 64)

        def apply_s(v):
            return kops.srht_gram_apply(v, signs, mask, use_pallas=spec.use_pallas)

        xh, cg_it = _cg_resolvent_solve(y, rho, eps, apply_s, iters)
        record_cg_iters(cg_it)  # eager runs sample; under jit it's a tracer -> dropped
        b = _beta(spec, n, rho, eps=eps)

    scale = (b / n) if jnp.ndim(b) == 0 else (b / n)[:, None]
    return scale * xh


def _resolve_decode_method(spec) -> str:
    method = getattr(spec, "decode_method", "auto") or "auto"
    if method == "auto":
        proj = getattr(spec, "projection", None) or "srht"
        return "fused" if proj in ("srht", "subsample") else "gram"
    return method


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    method = _resolve_decode_method(spec)
    record_decode_route("rand_proj_spatial", method)
    if method == "fused":
        proj = getattr(spec, "projection", None) or "srht"
        if proj == "gauss":
            raise ValueError(
                'decode_method="fused" needs an SRHT or subsample projection '
                "(gauss maps have no FWHT structure) — use gram/direct/auto"
            )
        return _decode_fused(spec, key, payloads, n, client_ids, chunk_offset)
    vals = payloads["vals"]  # (n, C, k)
    norm_sq = payloads.get("norm_sq")  # (n, C) or None
    z = jnp.moveaxis(vals, 0, 1).astype(jnp.float32)  # (C, n, k)
    dec = _decode_one_gram if method == "gram" else _decode_one_direct
    if spec.shared_randomness:
        a = _stack_a(spec, key, n, client_ids=client_ids)
        return dec(spec, n, a, z, norm_sq)

    c = vals.shape[1]

    def per_chunk(chunk_id, z_c, nsq_c):
        a = _stack_a(spec, key, n, chunk_id, client_ids=client_ids)
        nsq = None if norm_sq is None else nsq_c[:, None]
        return dec(spec, n, a, z_c[None], nsq)[0]

    # chunk_offset keys the per-chunk {G_i} draws by GLOBAL chunk position,
    # so an owner's chunk-slice decode re-derives the full decode's maps.
    return jax.vmap(per_chunk)(
        chunk_offset + jnp.arange(c), z,
        jnp.zeros((c, n)) if norm_sq is None else jnp.moveaxis(norm_sq, 0, 1),
    )


def self_decode(spec, key, client_id, payload):
    """Unbiased per-client reconstruction (d/k) G_i^T z_i.

    E[G^T G] = (k/d) I for all three projections (SRHT, subsample, gauss), so
    this is the client's unbiased contribution as the server sees it. With
    projection="subsample" it equals Rand-k's (d/k) scatter bit-for-bit
    (Lemma 4.1), so error feedback composes identically across the pair.
    """
    ckey = base.client_key(key, client_id)
    vals = payload["vals"].astype(jnp.float32)  # (C, k)
    scale = spec.d_block / spec.k
    if spec.shared_randomness:
        g = _g_matrix(spec, _client_draw(spec, ckey))  # (k, d)
        return scale * (vals @ g)
    c = vals.shape[0]
    keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
    gs = jax.vmap(lambda kk: _g_matrix(spec, _client_draw(spec, kk)))(keys)
    return scale * jnp.einsum("ck,ckd->cd", vals, gs)


CODEC = base.Codec(encode=encode, decode=decode, self_decode=self_decode)
base.register("rand_proj_spatial", CODEC)
