"""Induced compressor (Horvath & Richtarik 2021) with Top-k1 + Rand-k2.

C(x) = Top_{k1}(x) + RandK_{k2}(x - Top_{k1}(x)) * (d/k2)-scaled — unbiased,
because the Rand-k stage is an unbiased estimator of the Top-k residual.
Budget split k1 = round(topk_frac * k), k2 = k - k1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base, top_k


def _split(spec):
    k1 = max(1, int(round(spec.topk_frac * spec.k)))
    k1 = min(k1, spec.k - 1) if spec.k > 1 else 0
    return k1, spec.k - k1


def encode(spec, key, client_id, x_cd):
    k1, k2 = _split(spec)
    ckey = base.client_key(key, client_id)
    c, d = x_cd.shape

    _, tidx = jax.lax.top_k(jnp.abs(x_cd), max(k1, 1))
    tvals = jnp.take_along_axis(x_cd, tidx, axis=-1)
    if k1 == 0:
        tvals = jnp.zeros((c, 1), x_cd.dtype)
        tidx = jnp.zeros((c, 1), jnp.int32)
    resid = x_cd.at[jnp.arange(c)[:, None], tidx].add(-tvals) if k1 > 0 else x_cd

    keys = jax.vmap(base.chunk_key, in_axes=(None, 0))(ckey, jnp.arange(c))
    ridx = jax.vmap(lambda kk: jax.random.permutation(kk, d)[:k2])(keys)
    rvals = jnp.take_along_axis(resid, ridx, axis=-1)
    return {
        "top_vals": tvals,
        "top_idx": tidx.astype(jnp.int32),
        "rand_vals": rvals,
        "rand_idx": ridx.astype(jnp.int32),
    }


def decode(spec, key, payloads, n, client_ids=None, chunk_offset=0):
    # both index sets travel in the payload: position-free decode.
    k1, k2 = _split(spec)
    d = spec.d_block
    top = top_k.scatter_mean(payloads["top_vals"], payloads["top_idx"], n, d)
    rand = top_k.scatter_mean(payloads["rand_vals"], payloads["rand_idx"], n, d)
    return top + (d / k2) * rand


def self_decode(spec, key, client_id, payload):
    """Unbiased per-client reconstruction: Top part is exact, Rand part is the
    (d/k2)-scaled scatter — composes with error feedback / state stages."""
    _, k2 = _split(spec)
    d = spec.d_block
    top = top_k.scatter_mean(payload["top_vals"][None], payload["top_idx"][None], 1, d)
    rand = top_k.scatter_mean(
        payload["rand_vals"][None], payload["rand_idx"][None], 1, d
    )
    return top + (d / k2) * rand


base.register(
    "induced", base.Codec(encode=encode, decode=decode, self_decode=self_decode)
)
