"""The paper's contribution: correlation-aware sparsified mean estimation.

Public surface:
    codec                — the composable codec pipeline API (Payload, Stage
                           configs, Pipeline, ClientState) — THE estimator API
    mean_estimate, encode, decode — functional conveniences (accept a
                           Pipeline or a sparsifier config)
    chunking             — framework-scale blockwise application
    correlation.r_exact  — paper Eq. 7

The deprecated flat ``EstimatorSpec`` is removed; ``codec.build(name,
**old_kwargs)`` is the keyword-compatible constructor.
"""
from . import beta, chunking, correlation, transforms  # noqa: F401
from .estimators import (  # noqa: F401
    decode,
    encode,
    encode_all,
    mean_estimate,
    names,
)
from . import codec  # noqa: F401  (after .estimators: codec reads the registry)
