"""The paper's contribution: correlation-aware sparsified mean estimation.

Public surface:
    EstimatorSpec, mean_estimate, encode, decode  — the DME codec family
    chunking                                      — framework-scale blockwise application
    correlation.r_exact                           — paper Eq. 7
"""
from . import beta, chunking, correlation, transforms  # noqa: F401
from .estimators import (  # noqa: F401
    EstimatorSpec,
    decode,
    encode,
    encode_all,
    mean_estimate,
    names,
)
