"""The paper's contribution: correlation-aware sparsified mean estimation.

Public surface:
    codec                — the composable codec pipeline API (Payload, Stage
                           configs, Pipeline, ClientState) — THE estimator API
    mean_estimate, encode, decode — functional conveniences (accept a
                           Pipeline, a sparsifier config, or the deprecated
                           EstimatorSpec)
    chunking             — framework-scale blockwise application
    correlation.r_exact  — paper Eq. 7
    EstimatorSpec        — DEPRECATED flat spec; converts via codec.as_pipeline
"""
from . import beta, chunking, correlation, transforms  # noqa: F401
from .estimators import (  # noqa: F401
    EstimatorSpec,
    decode,
    encode,
    encode_all,
    mean_estimate,
    names,
)
from . import codec  # noqa: F401  (after .estimators: codec reads the registry)
