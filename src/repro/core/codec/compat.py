"""Back-compat: the old flat ``EstimatorSpec`` -> codec ``Pipeline``.

``build(name, **old_style_kwargs)`` is the one conversion point: it maps the
deprecated cross-cutting spec fields onto the typed per-estimator configs
(``wangni_capacity`` -> ``Wangni.capacity``, ``induced_topk_frac`` ->
``Induced.topk_frac``, ``payload_dtype`` -> a quantizer stage, ``ef`` -> an
``ErrorFeedback`` stage) and silently drops old spec fields that do not
apply to the chosen sparsifier (the old dataclass carried every field for
every estimator; e.g. ``transform`` on rand_k was always ignored). Unknown
keyword names still raise, so typos do not vanish.

``as_pipeline`` is the boundary normaliser every migrated subsystem calls:
Pipeline -> itself, bare Sparsifier config -> one-stage Pipeline,
EstimatorSpec -> converted Pipeline. Constructing an ``EstimatorSpec`` warns
(once per process, DeprecationWarning); converting one here does not warn
again — the construction already did.
"""
from __future__ import annotations

import dataclasses

from ..estimators import base as est_base
from .pipeline import Pipeline
from .quantizers import QUANTIZERS
from .sparsifiers import SPARSIFIERS, Sparsifier
from .stages import ErrorFeedback, Temporal

# old EstimatorSpec field -> per-estimator config field
_FIELD_RENAMES = {"wangni_capacity": "capacity", "induced_topk_frac": "topk_frac"}


def _estspec_fields() -> set:
    return {f.name for f in dataclasses.fields(est_base.EstimatorSpec)}


def build(name: str, **kw) -> Pipeline:
    """Old-style construction of a new-style pipeline.

        build("rand_proj_spatial", k=64, d_block=1024, transform="avg",
              payload_dtype="int8", ef=True)
        == Pipeline([RandProjSpatial(k=64, d_block=1024, transform="avg"),
                     Int8Quant(), ErrorFeedback()])
    """
    if name not in SPARSIFIERS:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(SPARSIFIERS)}")
    payload_dtype = kw.pop("payload_dtype", "float32")
    ef = kw.pop("ef", False)
    temporal = kw.pop("temporal", False)
    cls = SPARSIFIERS[name]
    fields = {f.name for f in dataclasses.fields(cls)}
    cfg_kw = {}
    for key, value in kw.items():
        new_key = _FIELD_RENAMES.get(key, key)
        if new_key in fields:
            cfg_kw[new_key] = value
        elif key not in _estspec_fields():
            raise TypeError(
                f"{name!r} takes no field {key!r} (valid: {sorted(fields)})"
            )
        # else: a legacy spec field that does not apply to this sparsifier —
        # dropped, matching the old flat dataclass's behaviour.
    stages: list = [cls(**cfg_kw)]
    if payload_dtype != "float32":
        if payload_dtype not in QUANTIZERS:
            raise ValueError(
                f"unknown payload_dtype {payload_dtype!r}; "
                f"have float32, {', '.join(sorted(QUANTIZERS))}"
            )
        stages.append(QUANTIZERS[payload_dtype]())
    if ef:
        stages.append(ErrorFeedback())
    if temporal:
        stages.append(Temporal())
    return Pipeline(tuple(stages))


def spec_to_pipeline(spec: "est_base.EstimatorSpec") -> Pipeline:
    kw = {
        f.name: getattr(spec, f.name)
        for f in dataclasses.fields(spec)
        if f.name != "name"
    }
    return build(spec.name, **kw)


def as_pipeline(obj) -> Pipeline:
    """Normalise any codec-like object to a Pipeline."""
    if isinstance(obj, Pipeline):
        return obj
    if isinstance(obj, Sparsifier):
        return Pipeline((obj,))
    if isinstance(obj, est_base.EstimatorSpec):
        return spec_to_pipeline(obj)
    raise TypeError(
        f"expected Pipeline, sparsifier config or EstimatorSpec, got "
        f"{type(obj).__name__}"
    )
