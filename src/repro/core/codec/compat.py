"""Keyword-compatible pipeline construction + the boundary normaliser.

``build(name, **old_style_kwargs)`` is the one conversion point from the
historical flat-keyword style: it maps the old cross-cutting field names
onto the typed per-estimator configs (``wangni_capacity`` ->
``Wangni.capacity``, ``induced_topk_frac`` -> ``Induced.topk_frac``,
``payload_dtype`` -> a quantizer stage, ``ef`` -> an ``ErrorFeedback``
stage) and silently drops legacy field names that do not apply to the
chosen sparsifier (the old flat dataclass carried every field for every
estimator; e.g. ``transform`` on rand_k was always ignored). Unknown
keyword names still raise, so typos do not vanish.

``as_pipeline`` is the boundary normaliser every subsystem calls:
Pipeline -> itself, bare Sparsifier config -> one-stage Pipeline, anything
else -> TypeError. The deprecated ``EstimatorSpec`` branch (and its
``spec_to_pipeline`` converter) is deleted — the class no longer exists;
``build`` is the keyword-compatible survivor of that API.
"""
from __future__ import annotations

import dataclasses

from .entropy import EntropyCode
from .pipeline import Pipeline
from .quantizers import QUANTIZERS
from .sparsifiers import SPARSIFIERS, Sparsifier
from .stages import ErrorFeedback, Temporal

# old flat-spec field -> per-estimator config field
_FIELD_RENAMES = {"wangni_capacity": "capacity", "induced_topk_frac": "topk_frac"}

# The field names of the deleted flat EstimatorSpec, frozen as the set of
# legacy keywords ``build`` silently DROPS when the chosen sparsifier has no
# such field (matching the old dataclass's carry-every-field behaviour).
# Anything outside this set that the sparsifier does not take is a typo and
# raises.
_LEGACY_FIELDS = frozenset({
    "name", "k", "d_block", "transform", "r_value", "r_mode",
    "shared_randomness", "decode_method", "projection", "beta_trials",
    "use_pallas", "wangni_capacity", "induced_topk_frac", "ef",
    "payload_dtype",
})


def build(name: str, **kw) -> Pipeline:
    """Old-style keyword construction of a new-style pipeline.

        build("rand_proj_spatial", k=64, d_block=1024, transform="avg",
              payload_dtype="int8", ef=True)
        == Pipeline([RandProjSpatial(k=64, d_block=1024, transform="avg"),
                     Int8Quant(), ErrorFeedback()])
    """
    if name not in SPARSIFIERS:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(SPARSIFIERS)}")
    payload_dtype = kw.pop("payload_dtype", "float32")
    ef = kw.pop("ef", False)
    temporal = kw.pop("temporal", False)
    entropy_code = kw.pop("entropy_code", False)
    cls = SPARSIFIERS[name]
    fields = {f.name for f in dataclasses.fields(cls)}
    cfg_kw = {}
    for key, value in kw.items():
        new_key = _FIELD_RENAMES.get(key, key)
        if new_key in fields:
            cfg_kw[new_key] = value
        elif key not in _LEGACY_FIELDS:
            raise TypeError(
                f"{name!r} takes no field {key!r} (valid: {sorted(fields)})"
            )
        # else: a legacy spec field that does not apply to this sparsifier —
        # dropped, matching the old flat dataclass's behaviour.
    stages: list = [cls(**cfg_kw)]
    if payload_dtype != "float32":
        if payload_dtype not in QUANTIZERS:
            raise ValueError(
                f"unknown payload_dtype {payload_dtype!r}; "
                f"have float32, {', '.join(sorted(QUANTIZERS))}"
            )
        stages.append(QUANTIZERS[payload_dtype]())
    if ef:
        stages.append(ErrorFeedback())
    if temporal:
        stages.append(Temporal())
    if entropy_code:
        stages.append(EntropyCode())
    return Pipeline(tuple(stages))


def as_pipeline(obj) -> Pipeline:
    """Normalise any codec-like object to a Pipeline."""
    if isinstance(obj, Pipeline):
        return obj
    if isinstance(obj, Sparsifier):
        return Pipeline((obj,))
    raise TypeError(
        f"expected Pipeline or sparsifier config, got {type(obj).__name__}"
        + (" (the deprecated EstimatorSpec was removed; use "
           "codec.build(name, **kwargs))" if type(obj).__name__ ==
           "EstimatorSpec" else "")
    )
