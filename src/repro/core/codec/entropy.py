"""Entropy-coded wire accounting: the ``EntropyCode`` stage (role ``"code"``).

The byte ledger has always been exact for the RAW wire format
(``payload.meta.declared_nbytes == payload.nbytes``). This stage extends the
same honesty contract to the entropy-coded format: ``coded_nbytes`` is the
EXACT length of the byte stream a wire encoder would emit — verified by
actually emitting it (``encode_stream``) and round-tripping it back
(``decode_stream``) in the property suite, per sparsifier x quantizer.

The stage is accounting-layer only: simulation arrays stay raw on device
(the decode math is unchanged and bit-identical with or without the stage);
what changes is what the ledger CHARGES — ``History.coded_bytes``, the
``bytes_coded`` trace annotations and ``fl.run --metrics-json`` all report
the coded size when the stage is present.

Wire format (schema-driven, deterministic):

* arrays are coded in sorted-name order (the payload pytree order);
* float arrays pass through raw, no header (the schema already pins shape
  and dtype, so nothing needs to be self-delimiting);
* int32 (index) arrays get a 1-byte header — the Rice-Golomb parameter
  ``r``, or the ``_STORE`` escape — followed by the Rice stream over
  zigzag-mapped symbols: indices live in [0, d), far below the 32-bit
  range, so Rice wins by ~2-3x;
* int8 (quantized value) arrays get a 1-byte header — a discrete-Gaussian
  scale index, or ``_STORE`` — followed by a static-model arithmetic-coded
  stream. Quantized values fill the int8 range by construction (the scale
  normalises the chunk max to ~127), so no prefix code can beat 8
  bits/symbol; a static Gaussian frequency table (rebuilt deterministically
  from the 1-byte scale index) codes at the distribution's ~7.5-bit
  entropy instead. A parameter-free adaptive model would pay more in
  learning redundancy than the ~0.5 bit/symbol it could win at these array
  sizes, which is why the model is parametric + static.

Whatever the path, the escape bounds a coded integer array at raw size + 1
header byte, and in the quantized/indexed regimes the coded size is
strictly smaller — the ``bench_artifacts.py extract quant`` gate keeps
that true continuously.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import numpy as np

from .payload import arrays_of, meta_of

_STORE = 255          # header escape: raw little-endian pass-through
_MAX_RICE_R = 40      # zigzag(int32) fits in 33 bits; scan a little past
_MAX_SIGMA = 254      # discrete-Gaussian scale indices 1.._MAX_SIGMA
_FREQ_SCALE = 1 << 14  # static-model frequency precision (total < 2^23)


def _is_integer(arr) -> bool:
    return np.issubdtype(np.asarray(arr).dtype, np.integer)


def _zigzag(arr) -> np.ndarray:
    """Signed -> unsigned, small magnitudes -> small symbols."""
    a = np.asarray(arr, np.int64).ravel()
    return ((a << 1) ^ (a >> 63)).astype(np.uint64)


def _rice_nbits(sym: np.ndarray, r: int) -> int:
    """Exact Rice stream length in bits: unary quotient (q ones + stop 0)
    plus ``r`` remainder bits per symbol."""
    return int(np.sum(sym >> np.uint64(r))) + sym.size * (1 + r)


def _best_rice(sym: np.ndarray) -> tuple[int, int]:
    """(r, nbits) minimising the exact coded length over r in [0, 40]."""
    best_r, best_bits = 0, _rice_nbits(sym, 0)
    for r in range(1, _MAX_RICE_R + 1):
        bits = _rice_nbits(sym, r)
        if bits < best_bits:
            best_r, best_bits = r, bits
    return best_r, best_bits


@functools.lru_cache(maxsize=None)
def _gauss_freqs(sigma_idx: int) -> tuple:
    """Deterministic integer frequency table for int8 symbols -128..127 under
    a discrete Gaussian of scale ``sigma_idx`` (Laplace-floored at 1 so every
    symbol stays codable). Returns (freqs int64[256], cumfreqs int64[257])."""
    s = np.arange(-128, 128, dtype=np.float64)
    p = np.exp(-0.5 * (s / float(sigma_idx)) ** 2)
    freqs = np.maximum(1, np.round(_FREQ_SCALE * p / p.sum())).astype(np.int64)
    cum = np.zeros(257, np.int64)
    np.cumsum(freqs, out=cum[1:])
    return freqs, cum


def _sigma_index(a: np.ndarray) -> int:
    """1-byte model parameter: the values' std, clipped onto the grid."""
    sd = float(np.std(np.asarray(a, np.float64)))
    return int(np.clip(round(sd), 1, _MAX_SIGMA))


def _array_coded_nbytes(arr) -> int:
    """Exact coded size of ONE array (== len(_encode_array(arr)))."""
    return len(_encode_array(arr))


class _BitWriter:
    def __init__(self):
        self._out = bytearray()
        self._acc = 0
        self._nbits = 0

    def write(self, value: int, nbits: int) -> None:
        for i in range(nbits - 1, -1, -1):  # MSB first
            self._acc = (self._acc << 1) | ((value >> i) & 1)
            self._nbits += 1
            if self._nbits == 8:
                self._out.append(self._acc)
                self._acc = self._nbits = 0

    def write_unary(self, q: int) -> None:
        while q >= 8:  # bulk 0xFF runs keep large quotients cheap enough
            self.write(0xFF, 8)
            q -= 8
        self.write((1 << (q + 1)) - 2, q + 1)  # q ones then the stop 0

    def getvalue(self) -> bytes:
        out = bytes(self._out)
        if self._nbits:
            out += bytes([self._acc << (8 - self._nbits)])
        return out


class _BitReader:
    def __init__(self, data: bytes, offset: int):
        self._data = data
        self._byte = offset
        self._bit = 0

    def read(self, nbits: int) -> int:
        v = 0
        for _ in range(nbits):
            bit = (self._data[self._byte] >> (7 - self._bit)) & 1
            v = (v << 1) | bit
            self._bit += 1
            if self._bit == 8:
                self._bit = 0
                self._byte += 1
        return v

    def read_unary(self) -> int:
        q = 0
        while self.read(1):
            q += 1
        return q

    def byte_end(self) -> int:
        return self._byte + (1 if self._bit else 0)


# ---------------------------------------------------------------- arithmetic
# Witten-Neal-Cleary integer arithmetic coding, 32-bit registers, static
# frequency model. Exactly invertible; the coded length IS the size claim.

_AC_BITS = 32
_AC_FULL = (1 << _AC_BITS) - 1
_AC_HALF = 1 << (_AC_BITS - 1)
_AC_QTR = 1 << (_AC_BITS - 2)


def _arith_encode_u8(sym: np.ndarray, cum: np.ndarray) -> bytes:
    total = int(cum[-1])
    w = _BitWriter()
    low, high, pending = 0, _AC_FULL, 0

    def emit(bit):
        nonlocal pending
        w.write(bit, 1)
        if pending:
            w.write((0 if bit else (1 << pending) - 1), pending)
            pending = 0

    for s in sym.tolist():
        span = high - low + 1
        high = low + span * int(cum[s + 1]) // total - 1
        low = low + span * int(cum[s]) // total
        while True:
            if high < _AC_HALF:
                emit(0)
            elif low >= _AC_HALF:
                emit(1)
                low -= _AC_HALF
                high -= _AC_HALF
            elif low >= _AC_QTR and high < 3 * _AC_QTR:
                pending += 1
                low -= _AC_QTR
                high -= _AC_QTR
            else:
                break
            low <<= 1
            high = (high << 1) | 1
    pending += 1
    emit(0 if low < _AC_QTR else 1)
    return w.getvalue()


def _arith_decode_u8(data: bytes, offset: int, count: int,
                     freqs: np.ndarray, cum: np.ndarray) -> np.ndarray:
    total = int(cum[-1])
    br = _BitReader(data, offset)
    end = len(data)

    def read_bit():
        if br._byte >= end:
            return 0  # the encoder's implicit trailing zeros
        return br.read(1)

    value = 0
    for _ in range(_AC_BITS):
        value = (value << 1) | read_bit()
    low, high = 0, _AC_FULL
    out = np.empty(count, np.int64)
    cum_list = cum.tolist()
    for i in range(count):
        span = high - low + 1
        target = ((value - low + 1) * total - 1) // span
        # binary search the symbol whose [cum[s], cum[s+1]) holds target
        lo_s, hi_s = 0, 256
        while hi_s - lo_s > 1:
            mid = (lo_s + hi_s) // 2
            if cum_list[mid] <= target:
                lo_s = mid
            else:
                hi_s = mid
        s = lo_s
        out[i] = s
        high = low + span * cum_list[s + 1] // total - 1
        low = low + span * cum_list[s] // total
        while True:
            if high < _AC_HALF:
                pass
            elif low >= _AC_HALF:
                low -= _AC_HALF
                high -= _AC_HALF
                value -= _AC_HALF
            elif low >= _AC_QTR and high < 3 * _AC_QTR:
                low -= _AC_QTR
                high -= _AC_QTR
                value -= _AC_QTR
            else:
                break
            low <<= 1
            high = (high << 1) | 1
            value = (value << 1) | read_bit()
    return out


def _encode_array(arr) -> bytes:
    a = np.asarray(arr)
    if not _is_integer(a):
        return a.tobytes()
    raw = a.tobytes()
    if a.dtype.itemsize == 1:  # int8 values: static-Gaussian arithmetic
        sigma = _sigma_index(a)
        _, cum = _gauss_freqs(sigma)
        sym = (np.asarray(a, np.int64).ravel() + 128)
        stream = _arith_encode_u8(sym, cum)
        if len(stream) >= len(raw):
            return bytes([_STORE]) + raw
        return bytes([sigma]) + stream
    sym = _zigzag(a)  # wider ints (indices): Rice over zigzag
    r, bits = _best_rice(sym)
    if (bits + 7) // 8 >= len(raw):
        return bytes([_STORE]) + raw
    w = _BitWriter()
    for s in sym.tolist():
        w.write_unary(s >> r)
        if r:
            w.write(s & ((1 << r) - 1), r)
    return bytes([r]) + w.getvalue()


def _decode_array(data: bytes, offset: int, shape, dtype):
    dt = np.dtype(dtype)
    count = int(np.prod(shape, dtype=np.int64))
    if not np.issubdtype(dt, np.integer):
        n = count * dt.itemsize
        a = np.frombuffer(data[offset:offset + n], dtype=dt).reshape(shape)
        return a, offset + n
    header = data[offset]
    offset += 1
    if header == _STORE:
        n = count * dt.itemsize
        a = np.frombuffer(data[offset:offset + n], dtype=dt).reshape(shape)
        return a, offset + n
    if dt.itemsize == 1:
        freqs, cum = _gauss_freqs(header)
        # the coded segment's length is not stored: re-derive it by
        # re-encoding the decoded symbols (static model — deterministic)
        sym = _arith_decode_u8(data, offset, count, freqs, cum)
        nbytes = len(_arith_encode_u8(sym, cum))
        a = (sym - 128).astype(dt).reshape(shape)
        return a, offset + nbytes
    r = header
    br = _BitReader(data, offset)
    sym = np.empty(count, np.int64)
    for i in range(count):
        q = br.read_unary()
        rem = br.read(r) if r else 0
        sym[i] = (q << r) | rem
    signed = (sym >> 1) ^ -(sym & 1)  # un-zigzag
    return signed.astype(dt).reshape(shape), br.byte_end()


def _sorted_items(payload):
    arrays = arrays_of(payload)
    return [(n, arrays[n]) for n in sorted(arrays)]


@dataclasses.dataclass(frozen=True)
class EntropyCode:
    """Exact entropy-coded payload-size accounting (see module docstring)."""

    role: ClassVar[str] = "code"
    name: ClassVar[str] = "entropy"

    def coded_nbytes(self, payload) -> int:
        """Exact coded wire bytes of ONE client's payload (closed form;
        equals ``len(self.encode_stream(payload))`` — property-tested)."""
        return sum(_array_coded_nbytes(a) for _, a in _sorted_items(payload))

    def coded_nbytes_stacked(self, payload) -> int:
        """Summed coded bytes of a stacked payload (leading client axis):
        each client's stream is coded independently, exactly as the wire
        would carry it."""
        items = [(n, np.asarray(a)) for n, a in _sorted_items(payload)]
        if not items:
            return 0
        n_clients = items[0][1].shape[0]
        return sum(
            _array_coded_nbytes(a[i]) for i in range(n_clients)
            for _, a in items
        )

    def encode_stream(self, payload) -> bytes:
        """ONE client's payload -> the actual coded byte stream."""
        return b"".join(_encode_array(a) for _, a in _sorted_items(payload))

    def decode_stream(self, data: bytes, schema) -> dict:
        """Invert ``encode_stream`` given the declared schema (the meta's
        ``ArraySpec`` tuple); returns the array dict, bit-exact for integer
        arrays and byte-exact for raw float arrays."""
        import jax.numpy as jnp

        specs = {s.name: s for s in schema}
        out, offset = {}, 0
        for name in sorted(specs):
            s = specs[name]
            a, offset = _decode_array(
                data, offset, tuple(s.shape), np.dtype(getattr(jnp, s.dtype))
            )
            out[name] = a
        if offset != len(data):
            raise ValueError(
                f"coded stream has {len(data) - offset} trailing bytes the "
                "schema does not account for"
            )
        return out


def coded_payload_nbytes(pipe, payload) -> int:
    """Coded wire bytes of a stacked payload under ``pipe``'s code stage —
    the raw actual bytes when the pipeline carries no code stage (so callers
    can ledger one 'coded' column unconditionally)."""
    code = getattr(pipe, "code_stage", None)
    if code is None:
        return payload.nbytes if meta_of(payload) is not None else sum(
            np.asarray(a).nbytes for a in arrays_of(payload).values()
        )
    return code.coded_nbytes_stacked(payload)
