"""The composable codec pipeline: ordered stages -> one estimator.

    Pipeline([RandProjSpatial(k=64, d_block=1024), Int8Quant(), ErrorFeedback()])

A pipeline owns exactly one sparsifier, at most one quantizer, and the
optional stateful stages (error feedback, temporal side information). The
dataflow is fixed by role, not list position:

    encode:  x  --temporal subtract--> --EF add residual--> sparsify
                --quantize--> Payload            (client side)
    decode:  Payload --dequantize--> sparsifier decode --side add-back--> x̂
                                                  (server side)

``encode`` threads client-held state (``ClientState``) explicitly and
returns the updated state next to the payload; stateless pipelines return
``state=None`` and cost nothing. The payload is self-describing
(``payload.meta``: budget, stage stack, declared byte schema), and
``decode`` trusts the PAYLOAD's budget over its own config — that is what
lets one decode path serve heterogeneous-k cohorts on any backend.

All stages are frozen dataclasses, so a ``Pipeline`` is hashable and can be
closed over by jit / passed as a static argument.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ... import obs
from ..estimators import base as est_base
from .payload import LEGACY_VALUE_NAMES, Payload, PayloadMeta, arrays_of, meta_of
from .sparsifiers import Sparsifier
from .stages import ClientState


@dataclasses.dataclass(frozen=True)
class Pipeline:
    stages: tuple

    def __post_init__(self):
        object.__setattr__(self, "stages", tuple(self.stages))
        by_role: dict = {}
        for s in self.stages:
            role = getattr(s, "role", None)
            if role not in ("sparsify", "quantize", "feedback", "temporal",
                            "code"):
                raise TypeError(f"{s!r} is not a codec stage (role={role!r})")
            by_role.setdefault(role, []).append(s)
            if len(by_role[role]) > 1:
                raise ValueError(f"pipeline has more than one {role!r} stage")
        if "sparsify" not in by_role:
            raise ValueError("pipeline needs exactly one sparsifier stage")

    # ------------------------------------------------------------ structure

    @property
    def sparsifier(self) -> Sparsifier:
        return next(s for s in self.stages if s.role == "sparsify")

    @property
    def quantizer(self):
        return next((s for s in self.stages if s.role == "quantize"), None)

    @property
    def ef_stage(self):
        return next((s for s in self.stages if s.role == "feedback"), None)

    @property
    def temporal_stage(self):
        return next((s for s in self.stages if s.role == "temporal"), None)

    @property
    def code_stage(self):
        """The entropy-coding (wire-accounting) stage, or None."""
        return next((s for s in self.stages if s.role == "code"), None)

    @property
    def has_ef(self) -> bool:
        return self.ef_stage is not None

    @property
    def has_client_temporal(self) -> bool:
        t = self.temporal_stage
        return t is not None and t.per_client

    @property
    def stateful(self) -> bool:
        return self.has_ef or self.has_client_temporal

    @property
    def non_streamable_stage(self):
        """The first stage that breaks chunk-streamability, as a
        ``(stage, reason)`` pair — or None when the pipeline streams.

        Streamability breaks when per-chunk ENCODE randomness depends on the
        chunk's position in the array: data-dependent sparsifiers (top_k) and
        the identity are position-free; the rand_k / SRHT family is
        position-free iff ``shared_randomness=True`` (one draw serves every
        chunk); wangni / induced key every chunk by position
        (``fold_in(ckey, chunk_position)``); ``Int8Quant`` draws its
        stochastic-rounding noise over the full array shape, so a slice draws
        different noise.
        """
        sp = self.sparsifier
        if sp.name not in ("top_k", "identity") and not getattr(
            sp, "shared_randomness", False
        ):
            return sp, (
                "keys each chunk's encode randomness by its position in the "
                "array (shared_randomness=False / per-chunk fold_in), so a "
                "chunk slice encodes differently than the same rows of the "
                "full array"
            )
        if getattr(sp, "chunk_budgets", None) is not None:
            return sp, (
                "allocates an explicit per-chunk budget vector over the FULL "
                "chunk axis (adaptive budgets), so a chunk slice's flat "
                "payload layout depends on the other chunks' budgets"
            )
        q = self.quantizer
        if q is not None and q.name in ("int8", "correlated"):
            return q, (
                "draws stochastic-rounding noise over the full array shape, "
                "so a chunk slice draws different noise"
            )
        return None

    @property
    def chunk_streamable(self) -> bool:
        """True when encode/decode of a chunk *slice* is bit-identical to the
        same rows of a whole-vector encode/decode — the precondition for the
        overlapped (double-buffered) collectives (``dist.collectives``,
        ``overlap=True``). See ``non_streamable_stage`` for the reasons."""
        return self.non_streamable_stage is None

    @property
    def non_shardable_stage(self):
        """The first stage whose DECODE mixes statistics across chunks, as a
        ``(stage, reason)`` pair — or None when every chunk's decode reads
        only its own payload rows (plus its global position).

        This is the precondition for the sharded server decode
        (``dist.collectives``, ``ownership=``): an owner decodes only the
        chunk slice it owns, so a cross-chunk decode statistic would change
        with the partition. It is strictly weaker than ``chunk_streamable``
        — clients always encode their FULL vector, so position-keyed encodes
        (wangni, induced, ``shared_randomness=False``) and full-array
        rounding noise (``Int8Quant``) are all fine; only the decodes whose
        online R-hat pools the statistics of every chunk into one scalar rho
        break it: ``rand_k_spatial(r_mode='est')`` and
        ``sparse_proj(r_mode='est')`` (sparse rows overlap across clients,
        so there is no exact per-chunk norm identity to shard the R-hat on).
        """
        sp = self.sparsifier
        if sp.name in ("rand_k_spatial", "sparse_proj") and \
                getattr(sp, "r_mode", "fixed") == "est":
            return sp, (
                "pools its online R-hat statistic across ALL chunks (one "
                "scalar rho per decode), so an owner's chunk-slice decode "
                "would estimate a different rho than the full decode"
            )
        if getattr(sp, "chunk_budgets", None) is not None:
            return sp, (
                "packs adaptive per-chunk budgets into ONE flat value row "
                "(no per-chunk payload axis), so an owner cannot slice out "
                "just its own chunks' rows"
            )
        return None

    @property
    def decode_shardable(self) -> bool:
        """True when the decode of a chunk slice (at its global offset) is
        bit-identical to the same rows of the full decode — the precondition
        for chunk-ownership sharded decoding."""
        return self.non_shardable_stage is None

    # convenience forwards (the attributes drivers/benchmarks report on)
    @property
    def name(self) -> str:
        return self.sparsifier.name

    @property
    def k(self) -> int:
        return self.sparsifier.budget

    @property
    def d_block(self) -> int:
        return self.sparsifier.d_block

    @property
    def transform(self):
        return getattr(self.sparsifier, "transform", None)

    def describe(self) -> str:
        return " | ".join(s.name for s in self.stages)

    # ------------------------------------------------------------- rebuilds

    def replace_sparsifier(self, _ignore_missing: bool = False, **kw) -> "Pipeline":
        sp = self.sparsifier
        fields = {f.name for f in dataclasses.fields(sp)}
        if _ignore_missing:
            kw = {k: v for k, v in kw.items() if k in fields}
        else:
            unknown = set(kw) - fields
            if unknown:
                raise TypeError(
                    f"sparsifier {sp.name!r} has no field(s) {sorted(unknown)}"
                )
        if not kw:
            return self
        new_sp = dataclasses.replace(sp, **kw)
        return Pipeline(tuple(new_sp if s is sp else s for s in self.stages))

    # the drop-in for the old ``spec.replace(...)``
    replace = replace_sparsifier

    def with_budget(self, k: int) -> "Pipeline":
        """Re-target the sparsifier at budget ``k`` (no-op for budget-free
        sparsifiers like identity, and when k already matches)."""
        if not hasattr(self.sparsifier, "k") or self.sparsifier.k == k:
            return self
        return self.replace_sparsifier(k=k)

    # --------------------------------------------------------------- ledger

    def payload_schema(self, n_chunks: int) -> tuple:
        schema = self.sparsifier.payload_schema(n_chunks)
        if self.quantizer is not None:
            schema = self.quantizer.transform_schema(schema)
        return schema

    def payload_meta(self, n_chunks: int) -> PayloadMeta:
        return PayloadMeta(
            budget=self.sparsifier.budget,
            d_block=self.d_block,
            stages=tuple(s.name for s in self.stages),
            schema=self.payload_schema(n_chunks),
            chunk_budgets=getattr(self.sparsifier, "chunk_budgets", None),
        )

    def payload_nbytes(self, n_chunks: int) -> int:
        """Declared per-client wire bytes for an ``n_chunks``-chunk vector."""
        return self.payload_meta(n_chunks).declared_nbytes

    # ------------------------------------------------------- stateless core

    def encode_payload(self, key, client_id, x_cd) -> Payload:
        """sparsify + quantize one client's (C, d_block) chunks."""
        arrays = self.sparsifier.encode(key, client_id, x_cd)
        meta = self.payload_meta(x_cd.shape[0])
        q = self.quantizer
        if q is not None:
            qkey = est_base.client_key(key, client_id)
            if getattr(q, "needs_round_key", False):
                # cohort-correlated quantizers derive their shared dither
                # from the ROUND key (constant across the vmapped cohort)
                # plus the client id — never from the per-client qkey alone
                arrays = q.encode(qkey, arrays, meta.value_names,
                                  round_key=key, client_id=client_id)
            else:
                arrays = q.encode(qkey, arrays, meta.value_names)
        return Payload(arrays=arrays, meta=meta)

    def _for_payload(self, payload) -> "Pipeline":
        """Trust the payload's self-described budget over our own config."""
        meta = meta_of(payload)
        if meta is None:
            return self
        pipe = self.with_budget(meta.budget)
        cb = getattr(meta, "chunk_budgets", None)
        if cb != getattr(pipe.sparsifier, "chunk_budgets", None) and \
                hasattr(pipe.sparsifier, "chunk_budgets"):
            pipe = pipe.replace_sparsifier(chunk_budgets=cb)
        return pipe

    def _dequantize(self, payload) -> dict:
        arrays = arrays_of(payload)
        if self.quantizer is None:
            return arrays
        meta = meta_of(payload)
        if meta is not None:
            names = meta.value_names
        else:  # legacy bare dict: only the historical value arrays quantize
            names = tuple(n for n in arrays if n in LEGACY_VALUE_NAMES)
        return self.quantizer.decode(arrays, names)

    def decode_payload(self, key, payloads, n: int, client_ids=None,
                       chunk_offset=0):
        """Stacked payloads (leading n) -> (C, d_block) mean estimate.

        ``chunk_offset``: global position of the payloads' first chunk — set
        by the sharded server decode, where an owner decodes only its own
        chunk slice (``dist.collectives``, ``ownership=``)."""
        pipe = self._for_payload(payloads)
        obs.count("codec", "decode.calls", sparsifier=pipe.sparsifier.name)
        obs.count("codec", "decode.clients", n)
        arrays = pipe._dequantize(payloads)
        return pipe.sparsifier.decode(key, arrays, n, client_ids=client_ids,
                                      chunk_offset=chunk_offset)

    def self_decode(self, key, client_id, payload):
        """One client's unbiased view of what the server attributes to it."""
        pipe = self._for_payload(payload)
        arrays = pipe._dequantize(payload)
        return pipe.sparsifier.self_decode(key, client_id, arrays)

    # ------------------------------------------------- stateful client side

    def init_client_state(self, n_clients: int, n_chunks: int):
        """Stacked (leading n_clients) ClientState, or None if stateless."""
        if not self.stateful:
            return None

        def rows(stage):
            if stage is None:
                return None
            row = stage.client_state(n_chunks, self.d_block)
            if row is None:
                return None
            return jnp.zeros((n_clients,) + row.shape, row.dtype)

        return ClientState(ef=rows(self.ef_stage), memory=rows(self.temporal_stage))

    def encode(self, key, client_id, x_cd, *, state: ClientState | None = None,
               side_info=None):
        """One client's full encode: temporal subtract -> EF add -> sparsify
        -> quantize, plus the state updates. Returns (Payload, new_state);
        new_state is None when no state was threaded in."""
        tstage = self.temporal_stage
        mem = state.memory if state is not None else None
        side = side_info
        if tstage is not None and tstage.per_client and mem is not None:
            side = mem  # the client's own memory IS its side information
        x_enc = x_cd if side is None else x_cd - side
        resid = state.ef if state is not None else None
        if self.has_ef and resid is not None:
            x_enc = x_enc + resid
        payload = self.encode_payload(key, client_id, x_enc)
        if state is None:
            return payload, None
        new_ef, new_mem = state.ef, state.memory
        update_mem = tstage is not None and tstage.per_client and mem is not None
        if (self.has_ef and resid is not None) or update_mem:
            recon = self.self_decode(key, client_id, payload)
            if self.has_ef and resid is not None:
                new_ef = x_enc - recon
            if update_mem:
                eta = tstage.resolve_eta(self.sparsifier.budget, self.d_block)
                new_mem = mem + eta * recon
        return payload, ClientState(ef=new_ef, memory=new_mem)

    def decode(self, key, payloads, n: int, *, client_ids=None, side_info=None,
               chunk_offset=0):
        """Server decode of stacked payloads; ``side_info`` is whatever must
        be added back (the broadcast estimate, or the mean of the survivors'
        mirrored memories for per-client temporal pipelines); ``chunk_offset``
        is the global position of the first chunk (owner-sliced decode)."""
        out = self.decode_payload(key, payloads, n, client_ids=client_ids,
                                  chunk_offset=chunk_offset)
        return out if side_info is None else out + side_info

    # ------------------------------------------------------------ batched

    def encode_all(self, key, xs, *, client_ids=None, side_info=None, states=None):
        """xs: (n, C, d) -> (stacked payloads, stacked new states | None).

        ``client_ids`` (n,) overrides the 0..n-1 assignment (participants of
        a larger cohort); ``states`` is a stacked ClientState for those same
        clients."""
        n = xs.shape[0]
        if obs.enabled():  # guard: payload_nbytes builds a PayloadMeta
            obs.count("codec", "encode_all.calls", sparsifier=self.sparsifier.name)
            obs.count("codec", "encode_all.clients", n)
            obs.count("codec", "encode_all.payload_bytes",
                      n * self.payload_nbytes(xs.shape[1]))
        ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)
        if states is None:
            payloads = jax.vmap(
                lambda i, x: self.encode(key, i, x, side_info=side_info)[0]
            )(ids, xs)
            return payloads, None
        return jax.vmap(
            lambda i, x, st: self.encode(key, i, x, state=st, side_info=side_info)
        )(ids, xs, states)

    def mean_estimate(self, key, xs, *, client_ids=None, side_info=None):
        """One-shot DME: xs (n, C, d) -> (C, d) mean estimate."""
        n = xs.shape[0]
        payloads, _ = self.encode_all(
            key, xs, client_ids=client_ids, side_info=side_info
        )
        return self.decode(
            key, payloads, n, client_ids=client_ids, side_info=side_info
        )
