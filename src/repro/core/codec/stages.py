"""Stage protocol + client-held state for the codec pipeline.

A ``Stage`` is one orthogonal link in a compression pipeline. Five roles
exist; a ``Pipeline`` validates at most one of each except quantizers, which
it validates to at most one as well (stacked quantization is not a thing we
model):

    sparsify  — (C, d_block) chunks -> payload arrays (exactly one per
                pipeline; see codec.sparsifiers)
    quantize  — payload arrays -> smaller payload arrays (codec.quantizers)
    feedback  — error-feedback residual carried in ClientState.ef
    temporal  — temporal side information (client-held memory, after
                Rand-k-Temporal, Jhunjhunwala et al. 2021)
    code      — entropy-coded wire accounting (codec.entropy.EntropyCode):
                arrays stay raw on device, the ledger charges the EXACT
                coded stream length

The stage hooks are ``encode`` / ``decode`` / ``self_decode`` (dataflow,
defined per role — see sparsifiers/quantizers) and ``client_state`` (the
per-client state a stateful stage owns). Stages are frozen dataclasses, so
pipelines are hashable and can be closed over by jit like the old spec.

``ClientState`` is the explicit home for everything a client carries across
rounds: the EF residual and the temporal memory, each a (n_chunks, d_block)
array per client (stacked to (n_clients, C, d) by the driver). It is a
pytree, so cohorts vmap/slice/scatter state rows exactly like data. The
server legitimately mirrors the temporal memory: updates depend only on
transmitted payloads (deterministic given the shared round key), so both
sides advance the same state without extra communication — that is what
makes the decode's side-information add-back exact (docs/DESIGN.md §8.2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ClientState:
    """Per-client cross-round state (stacked over clients by the driver).

    ``ef``      — error-feedback residual, (C, d_block) per client or None.
    ``memory``  — temporal memory m_i, (C, d_block) per client or None.
    """

    ef: Any = None
    memory: Any = None


def _state_flatten(s: ClientState):
    return (s.ef, s.memory), None


def _state_unflatten(_, children):
    return ClientState(ef=children[0], memory=children[1])


jax.tree_util.register_pytree_node(ClientState, _state_flatten, _state_unflatten)


@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Error-feedback stage: the client adds its residual to the input before
    encoding and keeps ``input - self_decode(payload)`` as the next residual,
    so mass a (semi-)biased codec drops is retransmitted until it lands.
    Residuals live in ``ClientState.ef`` — one row per client, so EF composes
    with heterogeneous budgets (each client's residual follows its own k_i)
    and with partial participation (non-participants' rows carry over).
    """

    role: ClassVar[str] = "feedback"
    name: ClassVar[str] = "error_feedback"

    def client_state(self, n_chunks: int, d_block: int):
        return jnp.zeros((n_chunks, d_block), jnp.float32)


@dataclasses.dataclass(frozen=True)
class Temporal:
    """Temporal side-information stage.

    ``per_client=True`` (default) is TRUE Rand-k-Temporal: client i encodes
    ``x_i - m_i`` against its OWN memory, and both sides advance
    ``m_i' = m_i + eta * self_decode(payload_i)`` — a deterministic function
    of the transmitted payload, so the server's mirror never desyncs. With
    Rand-k and ``eta = k/d`` (the ``eta=None`` default) this is exactly the
    paper's coordinate-replacement rule: (k/d) * (d/k) * scatter(vals) sets
    the transmitted coordinates to their fresh values. The server adds back
    the SURVIVORS' mean memory, which keeps the decode unbiased:
    mean(x_i) = mean(x_i - m_i) + mean(m_i).

    ``per_client=False`` is the broadcast variant (the server's previous
    estimate as everyone's side information) — equivalent to
    ``RoundConfig(temporal=True)``, kept for comparison.
    """

    role: ClassVar[str] = "temporal"
    name: ClassVar[str] = "temporal"

    per_client: bool = True
    eta: float | None = None  # None -> budget / d_block (coordinate replacement)

    def client_state(self, n_chunks: int, d_block: int):
        if not self.per_client:
            return None
        return jnp.zeros((n_chunks, d_block), jnp.float32)

    def resolve_eta(self, budget: int, d_block: int) -> float:
        return self.eta if self.eta is not None else budget / d_block
