"""Per-estimator sparsifier configs — the typed replacements for the
cross-cutting fields of the old flat spec style (``codec.build`` keywords).

Each config is a frozen dataclass carrying ONLY the fields its codec reads
(``RandK`` has no ``transform``; ``Wangni`` owns ``capacity``; ``Induced``
owns ``topk_frac``), and doubles as the spec object the registry codec
implementations (``core.estimators.*``) consume — the impl functions read
``spec.k`` / ``spec.d_block`` / etc., which are exactly these fields. The
``payload_schema`` hook is each codec's independent declaration of its wire
format; the ledger-honesty tests compare it against the arrays actually
produced.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar

from ..estimators import base as est_base
from .payload import AUX, INDICES, VALUES, ArraySpec


@dataclasses.dataclass(frozen=True)
class Sparsifier:
    """Base sparsifier stage: (C, d_block) chunk batch -> payload arrays."""

    role: ClassVar[str] = "sparsify"
    name: ClassVar[str] = ""

    @property
    def codec(self) -> est_base.Codec:
        return est_base.get(self.name)

    @property
    def budget(self) -> int:
        """Per-chunk transmitted-coordinate budget (k; d_block for identity)."""
        return getattr(self, "k", self.d_block)

    def encode(self, key, client_id, x_cd) -> dict:
        return self.codec.encode(self, key, client_id, x_cd)

    def decode(self, key, arrays, n, client_ids=None, chunk_offset=0):
        """``chunk_offset``: global position of the first chunk in ``arrays``.
        Non-zero for an owner's chunk-slice decode (the sharded server decode,
        ``dist.collectives``): position-keyed codecs re-derive randomness from
        the global chunk id, so a slice decodes bit-identically to the same
        rows of a full-array decode."""
        return self.codec.decode(self, key, arrays, n, client_ids=client_ids,
                                 chunk_offset=chunk_offset)

    @property
    def supports_self_decode(self) -> bool:
        return self.codec.self_decode is not None

    def self_decode(self, key, client_id, arrays):
        if self.codec.self_decode is None:
            raise ValueError(
                f"sparsifier {self.name!r} has no per-client reconstruction "
                "(self_decode); it cannot drive error feedback or temporal "
                "memories"
            )
        return self.codec.self_decode(self, key, client_id, arrays)

    def payload_schema(self, n_chunks: int) -> tuple:
        raise NotImplementedError

    @property
    def self_decode_norm_inflation(self) -> float:
        """``E||self_decode(x)||^2 / ||x||^2`` for this codec — the factor the
        online rho tracker (``fl.server.measure_rho``) divides out of the
        r_exact denominator. 1.0 for codecs whose per-client reconstruction
        does not inflate norms (identity, top_k, ...); the unbiased
        sparsifying families override with their exact second-moment factor.
        """
        return 1.0

    def replace(self, **kw) -> "Sparsifier":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RandK(Sparsifier):
    """Rand-k sparsification (Konecny & Richtarik 2018); indices key-derived.

    ``chunk_budgets`` (rand_k only) turns the uniform per-chunk budget k into
    an explicit per-chunk allocation ``(k_0, ..., k_{C-1})`` — the adaptive-
    budget mechanism (``fl.rounds`` derives it each round from per-chunk norm
    mass). The payload becomes ONE flat value row of ``sum(chunk_budgets)``
    entries; decode scales chunk c by ``d_block / k_c``, so each chunk's
    estimate stays exactly unbiased at its own budget. The allocator
    (``codec.adaptive_chunk_budgets``) conserves ``sum(k_c) == C * k``, so
    wire bytes are a pure reallocation, never a reduction.
    """

    name: ClassVar[str] = "rand_k"
    k: int = 64
    d_block: int = 1024
    shared_randomness: bool = True
    chunk_budgets: tuple | None = None  # per-chunk (k_0..k_{C-1}); rand_k only

    def __post_init__(self):
        cb = self.chunk_budgets
        if cb is None:
            return
        if type(self).name != "rand_k":
            raise ValueError(
                f"chunk_budgets is rand_k-only (the {type(self).name!r} "
                "decode transforms assume one uniform per-chunk budget); "
                "got chunk_budgets on it"
            )
        cb = tuple(int(b) for b in cb)
        if not cb or any(b < 1 or b > self.d_block for b in cb):
            raise ValueError(
                f"chunk_budgets must be non-empty with every entry in "
                f"[1, d_block={self.d_block}], got {cb}"
            )
        object.__setattr__(self, "chunk_budgets", cb)

    def payload_schema(self, n_chunks: int) -> tuple:
        if self.chunk_budgets is not None:
            if len(self.chunk_budgets) != n_chunks:
                raise ValueError(
                    f"chunk_budgets has {len(self.chunk_budgets)} entries but "
                    f"the vector has {n_chunks} chunks"
                )
            return (ArraySpec("vals", (sum(self.chunk_budgets),), "float32",
                              VALUES),)
        return (ArraySpec("vals", (n_chunks, self.k), "float32", VALUES),)

    @property
    def self_decode_norm_inflation(self) -> float:
        # E||(d/k) scatter_k(x)||^2 = (d/k) ||x||^2 per chunk. Under adaptive
        # chunk_budgets the factor is sum_c (d/k_c) ||x_c||^2 / ||x||^2; with
        # the proportional-to-mass allocation that produces the budgets
        # (k_c ∝ ||x_c||^2, sum k_c = C k) this collapses back to d/k exactly,
        # so the nominal budget stays the right de-inflation.
        return self.d_block / self.k


@dataclasses.dataclass(frozen=True)
class RandKSpatial(RandK):
    """Rand-k-Spatial decoding (Jhunjhunwala et al. 2021, paper Eq. 2/3)."""

    name: ClassVar[str] = "rand_k_spatial"
    transform: str = "avg"        # one|max|avg|opt (wavg resolved by fl.server)
    r_value: float | None = None  # oracle R for transform="opt"
    r_mode: str = "fixed"         # fixed | est (in-decode R-hat)

    def payload_schema(self, n_chunks: int) -> tuple:
        schema = super().payload_schema(n_chunks)
        if self.r_mode == "est":
            schema += (ArraySpec("norm_sq", (n_chunks,), "float32", AUX),)
        return schema


@dataclasses.dataclass(frozen=True)
class RandProjSpatial(RandK):
    """Rand-Proj-Spatial family (paper Eq. 5) — the core contribution."""

    name: ClassVar[str] = "rand_proj_spatial"
    transform: str = "avg"
    r_value: float | None = None
    r_mode: str = "fixed"
    # auto  -> "fused" for srht/subsample, "gram" for gauss
    # fused -> batched kernel fast path: matrix-free CG resolvent solve
    #          (docs/DESIGN.md §3.5, docs/KERNELS.md), no eigh
    # gram  -> nk x nk Gram eigendecomposition (docs/DESIGN.md §3.3)
    # direct-> paper-literal d x d eigh (oracle path)
    decode_method: str = "auto"
    projection: str = "srht"      # srht | subsample (Lemma 4.1) | gauss
    beta_trials: int | None = None
    use_pallas: str = "auto"
    ridge: float = 1e-2           # eps of the fused resolvent solve (T + eps)
    cg_iters: int = 64            # CG iteration cap of the fused decode

    def payload_schema(self, n_chunks: int) -> tuple:
        schema = (ArraySpec("vals", (n_chunks, self.k), "float32", VALUES),)
        if self.r_mode == "est":
            schema += (ArraySpec("norm_sq", (n_chunks,), "float32", AUX),)
        return schema

    def encode_flops_per_chunk(self) -> int:
        """Analytic per-chunk encode flop model: the FWHT's d log2(d)
        adds plus the sign flip and scale (SparseProj's comparison line)."""
        return int(self.d_block * (math.log2(self.d_block) + 2))


@dataclasses.dataclass(frozen=True)
class SparseProj(Sparsifier):
    """Very-sparse random projection (Achlioptas 2003; Li et al. 2006) with
    the paper's correlation-aware Gram-resolvent decode.

    Each of the k rows of G holds ``nnz = round(d_block / s)`` signed entries
    of magnitude 1/sqrt(nnz) at key-derived columns (CSR-style column
    sampling; the classic ±sqrt(s/k) matrix rescaled onto the family's
    E[G^T G] = (k/d) I, unit-row-norm convention). ``s`` is the density
    divisor: encode costs O(k d / s) flops vs the SRHT's O(d log d) — the
    cheap-encode point of the accuracy-vs-compute frontier. The projection
    is drawn deterministically from the round key, so the server reconstructs
    it without it ever crossing the wire.

    ``r_mode="est"`` pools its online R-hat across ALL chunks into one scalar
    rho (sparse rows overlap, so there is no exact per-chunk norm identity to
    shard on) — that mode is decode-NON-shardable and the ownership gate
    rejects it by name; the fixed-transform modes shard bitwise.
    """

    name: ClassVar[str] = "sparse_proj"
    k: int = 64
    d_block: int = 1024
    s: float = 16.0               # density divisor: nnz per row = d_block / s
    shared_randomness: bool = True
    transform: str = "avg"        # one|max|avg|opt (wavg resolved by fl.server)
    r_value: float | None = None
    r_mode: str = "fixed"         # fixed | est (pooled online R-hat)
    beta_trials: int | None = None
    ridge: float = 1e-2           # eps of the resolvent solve (T + eps)
    cg_iters: int = 64            # CG iteration cap of the decode

    def __post_init__(self):
        if self.s < 1.0:
            raise ValueError(f"density divisor s must be >= 1, got {self.s}")

    @property
    def nnz(self) -> int:
        """Signed entries per projection row: round(d_block / s), >= 1."""
        return max(1, min(self.d_block, int(round(self.d_block / self.s))))

    def payload_schema(self, n_chunks: int) -> tuple:
        schema = (ArraySpec("vals", (n_chunks, self.k), "float32", VALUES),)
        if self.r_mode == "est":
            schema += (ArraySpec("norm_sq", (n_chunks,), "float32", AUX),)
        return schema

    def encode_flops_per_chunk(self) -> int:
        """Analytic per-chunk encode flop model: one multiply + one add per
        stored entry, plus the row scale. Strictly decreasing in ``s``."""
        return int(self.k * (2 * self.nnz + 1))

    @property
    def self_decode_norm_inflation(self) -> float:
        """Exact second moment of the with-replacement very-sparse decode:

            E||(d/k) G^T G x||^2 = (d/k) * F * ||x||^2,
            F = 1 + (k-1)/d + 2(nnz-1)/(nnz*d)

        Unlike the SRHT family (G G^T = I_k, factor exactly d/k), the rows
        g = (1/sqrt(nnz)) sum_t sigma_t e_{c_t} are drawn with replacement:
        E||g||^4 = 1 + 2(nnz-1)/(nnz*d) (duplicate-column fourth-moment term)
        and the k rows are independent rather than orthogonal, adding the
        (k-1)/d cross-row term. Limits check out: nnz=1 gives the exact
        subsample factor 1 + (k-1)/d on top of d/k, and F -> 1 as the rows
        orthogonalise (d -> inf). MC-verified in tests/test_properties.py.
        """
        d, k, nnz = self.d_block, self.k, self.nnz
        f = 1.0 + (k - 1.0) / d + 2.0 * (nnz - 1.0) / (nnz * d)
        return (d / k) * f


@dataclasses.dataclass(frozen=True)
class TopK(Sparsifier):
    """Top-k (Shi et al. 2019): data-dependent indices DO travel."""

    name: ClassVar[str] = "top_k"
    k: int = 64
    d_block: int = 1024

    def payload_schema(self, n_chunks: int) -> tuple:
        return (
            ArraySpec("vals", (n_chunks, self.k), "float32", VALUES),
            ArraySpec("idx", (n_chunks, self.k), "int32", INDICES),
        )


@dataclasses.dataclass(frozen=True)
class Wangni(Sparsifier):
    """Non-uniform adaptive sparsification (Wangni et al. 2018)."""

    name: ClassVar[str] = "wangni"
    k: int = 64
    d_block: int = 1024
    capacity: float = 1.5  # fixed-shape payload capacity multiplier

    @property
    def capacity_slots(self) -> int:
        return int(math.ceil(self.capacity * self.k))

    def payload_schema(self, n_chunks: int) -> tuple:
        cap = self.capacity_slots
        return (
            ArraySpec("vals", (n_chunks, cap), "float32", VALUES),
            ArraySpec("idx", (n_chunks, cap), "int32", INDICES),
        )


@dataclasses.dataclass(frozen=True)
class Induced(Sparsifier):
    """Induced compressor (Horvath & Richtarik 2021): Top-k1 + Rand-k2."""

    name: ClassVar[str] = "induced"
    k: int = 64
    d_block: int = 1024
    topk_frac: float = 0.5  # budget split k1 = round(topk_frac * k)

    def split(self) -> tuple[int, int]:
        k1 = max(1, int(round(self.topk_frac * self.k)))
        k1 = min(k1, self.k - 1) if self.k > 1 else 0
        return k1, self.k - k1

    def payload_schema(self, n_chunks: int) -> tuple:
        k1, k2 = self.split()
        t = max(k1, 1)  # k1 == 0 still ships a (C, 1) zero placeholder
        return (
            ArraySpec("top_vals", (n_chunks, t), "float32", VALUES),
            ArraySpec("top_idx", (n_chunks, t), "int32", INDICES),
            ArraySpec("rand_vals", (n_chunks, k2), "float32", VALUES),
            ArraySpec("rand_idx", (n_chunks, k2), "int32", INDICES),
        )


@dataclasses.dataclass(frozen=True)
class Identity(Sparsifier):
    """No-compression baseline: the full chunk is the payload."""

    name: ClassVar[str] = "identity"
    d_block: int = 1024

    def payload_schema(self, n_chunks: int) -> tuple:
        return (ArraySpec("vals", (n_chunks, self.d_block), "float32", VALUES),)


SPARSIFIERS: dict[str, type] = {
    cls.name: cls
    for cls in (RandK, RandKSpatial, RandProjSpatial, SparseProj, TopK, Wangni,
                Induced, Identity)
}
