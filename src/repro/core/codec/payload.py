"""Self-describing payload container for the codec pipeline.

A ``Payload`` is what one client transmits for one round: a dict of arrays
(the only thing that crosses the wire) plus static ``PayloadMeta`` — the
per-client budget, the chunk geometry, the stage stack that produced it, and
a *declared* byte schema. The schema is computed from the pipeline config
alone (never from the arrays), so the ledger is an independent claim about
the wire format that tests can check against the actual array bytes
(``tests/test_codec_pipeline.py`` asserts ``declared == actual`` for every
registered sparsifier x quantizer combination — catching drift like an int8
scale array being added to the payload but not to the ledger).

``Payload`` is registered as a pytree whose children are the arrays (sorted
by name, deterministic) and whose aux data is the hashable meta, so payloads
vmap/stack/all_gather/index exactly like the anonymous dict payloads they
replace: ``jax.vmap`` over ``Pipeline.encode`` yields a stacked Payload with
a leading client axis and unchanged meta, and ``jax.tree.map`` rebuilds the
Payload around transformed leaves.

Budget metadata riding in the payload is what lets a server decode a
heterogeneous-k cohort without backend special-casing: the decode path reads
``payload.meta.budget`` instead of trusting its own config (``Pipeline``
re-derives the sparsifier at that budget when they disagree).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ArraySpec kinds
VALUES = "values"    # quantizable payload values (vals / top_vals / rand_vals)
INDICES = "indices"  # data-dependent coordinates (top_k / wangni / induced)
SCALES = "scales"    # quantization scales (added by Int8Quant)
AUX = "aux"          # side statistics (e.g. norm_sq for the online R-hat)

# The historical value-array names, for legacy bare-dict payloads that carry
# no schema (Payload.meta is the source of truth whenever present).
LEGACY_VALUE_NAMES = ("vals", "top_vals", "rand_vals")


class ArraySpec(NamedTuple):
    """One payload array's declared wire format."""

    name: str
    shape: tuple
    dtype: str
    kind: str

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * _itemsize(self.dtype)


def _itemsize(dtype: str) -> int:
    return np.dtype(getattr(jnp, dtype)).itemsize


@dataclasses.dataclass(frozen=True)
class PayloadMeta:
    """Static, hashable payload description (jit/vmap aux data)."""

    budget: int                      # per-client k this payload was encoded at
    d_block: int                     # chunk size the budget applies to
    stages: tuple = ()               # stage names, encode order
    schema: tuple = ()               # tuple[ArraySpec, ...]: declared wire format
    staleness: int = 0               # rounds between encode and decode (0 = fresh)
    chunk_budgets: tuple | None = None  # adaptive per-chunk (k_0..k_{C-1})

    @property
    def declared_nbytes(self) -> int:
        """Per-client wire bytes this payload CLAIMS to occupy (the ledger)."""
        return sum(s.nbytes for s in self.schema)

    def array_spec(self, name: str) -> ArraySpec:
        for s in self.schema:
            if s.name == name:
                return s
        raise KeyError(name)

    @property
    def value_names(self) -> tuple:
        return tuple(s.name for s in self.schema if s.kind == VALUES)


@dataclasses.dataclass
class Payload:
    """arrays: name -> array (per-client, or stacked with a leading client
    axis once vmapped); meta: static self-description."""

    arrays: dict
    meta: PayloadMeta

    @property
    def nbytes(self) -> int:
        """ACTUAL summed array bytes (all axes — leading client axis included
        when stacked). For an unstacked payload this must equal
        ``meta.declared_nbytes``; the ledger-honesty tests enforce it."""
        return sum(
            int(np.prod(a.shape, dtype=np.int64)) * np.dtype(a.dtype).itemsize
            for a in self.arrays.values()
        )

    def per_client_nbytes(self) -> int:
        """Actual bytes with the leading (client) axis stripped — the wire
        cost of ONE client's transmission inside a stacked payload."""
        return sum(
            int(np.prod(a.shape[1:], dtype=np.int64)) * np.dtype(a.dtype).itemsize
            for a in self.arrays.values()
        )

    def __getitem__(self, name: str):
        return self.arrays[name]


def _payload_flatten(p: Payload):
    names = tuple(sorted(p.arrays))
    return tuple(p.arrays[n] for n in names), (names, p.meta)


def _payload_unflatten(aux, children):
    names, meta = aux
    return Payload(arrays=dict(zip(names, children)), meta=meta)


jax.tree_util.register_pytree_node(Payload, _payload_flatten, _payload_unflatten)


def arrays_of(payload) -> dict:
    """Accept a Payload or a bare dict (legacy) and return the array dict."""
    if isinstance(payload, Payload):
        return payload.arrays
    if isinstance(payload, dict):
        return payload
    raise TypeError(f"expected Payload or dict, got {type(payload).__name__}")


def meta_of(payload) -> PayloadMeta | None:
    return payload.meta if isinstance(payload, Payload) else None


def with_staleness(payload: Payload, staleness: int) -> Payload:
    """Return ``payload`` re-tagged with ``meta.staleness = staleness``.

    Staleness is the number of rounds between a payload's encode and its
    decode: 0 is a fresh (synchronous) payload, 1 is a payload that missed
    its round's deadline and is admitted into the NEXT round's decode
    (buffered staleness-1 aggregation, ``fl.rounds`` async mode). The tag is
    pure metadata — arrays, wire bytes, and the declared schema are
    untouched, so a stale payload passes the same ledger-honesty check and
    decodes to the same numbers as its fresh twin (it is the *round key* of
    the decode that differs, not the payload).
    """
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    if not isinstance(payload, Payload):
        raise TypeError(f"expected Payload, got {type(payload).__name__}")
    meta = dataclasses.replace(payload.meta, staleness=staleness)
    return Payload(arrays=payload.arrays, meta=meta)


def check_against_schema(payload: Payload) -> list[str]:
    """Diff the actual arrays against the declared schema. Returns a list of
    human-readable mismatches (empty == the ledger is honest)."""
    problems = []
    schema = {s.name: s for s in payload.meta.schema}
    for name, arr in payload.arrays.items():
        if name not in schema:
            problems.append(f"array {name!r} not declared in schema")
            continue
        s = schema[name]
        if tuple(arr.shape) != tuple(s.shape):
            problems.append(f"{name}: shape {tuple(arr.shape)} != declared {s.shape}")
        if np.dtype(arr.dtype) != np.dtype(getattr(jnp, s.dtype)):
            problems.append(f"{name}: dtype {arr.dtype} != declared {s.dtype}")
    for name in schema:
        if name not in payload.arrays:
            problems.append(f"declared array {name!r} missing from payload")
    if payload.nbytes != payload.meta.declared_nbytes:
        problems.append(
            f"nbytes {payload.nbytes} != declared {payload.meta.declared_nbytes}"
        )
    return problems
