"""Quantizer stages: payload-array transforms (paper §7 future work —
sparsification x quantization).

A quantizer never touches the vector domain: it rewrites the VALUES arrays
of an already-sparsified payload (indices, scales and aux stats pass
through), declares the resulting wire format via ``transform_schema``, and
inverts itself on the server (and inside ``self_decode``, so error feedback
sees exactly what the server reconstructs — the residual absorbs the
quantization error too).

``Int8Quant`` uses per-chunk max scales + STOCHASTIC rounding, so any
unbiased sparsifier composed with it stays unbiased (property-tested in
tests/test_codec_pipeline.py). Salts for the rounding noise are stable
per-array-name fold_in tags, identical to the historical ``payload_dtype``
path, so migrated pipelines are bit-compatible with the old spec.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import ClassVar

import jax
import jax.numpy as jnp

from .payload import SCALES, VALUES, ArraySpec

# stable fold_in tags (legacy payload_dtype="int8" parity)
_SALTS = {"vals": 101, "top_vals": 211, "rand_vals": 307}


def _salt(name: str) -> int:
    # full 31-bit mask: an earlier 0x7FFFFFF (27-bit) typo needlessly raised
    # collision odds for non-canonical array names; the named _SALTS keep the
    # historical payload_dtype bit-compat regardless of the mask
    return _SALTS.get(name, int(zlib.crc32(name.encode()) & 0x7FFFFFFF))


@dataclasses.dataclass(frozen=True)
class Bf16Quant:
    """bfloat16 cast of the value arrays: 2x fewer bytes, unbiased-in-
    expectation is NOT claimed (bf16 rounding is deterministic) but the error
    is tiny relative to sparsification noise."""

    role: ClassVar[str] = "quantize"
    name: ClassVar[str] = "bf16"

    def encode(self, qkey, arrays: dict, value_names) -> dict:
        return {
            n: (v.astype(jnp.bfloat16) if n in value_names else v)
            for n, v in arrays.items()
        }

    def decode(self, arrays: dict, value_names) -> dict:
        return {
            n: (v.astype(jnp.float32) if n in value_names else v)
            for n, v in arrays.items()
        }

    def transform_schema(self, schema: tuple) -> tuple:
        return tuple(
            s._replace(dtype="bfloat16") if s.kind == VALUES else s for s in schema
        )


@dataclasses.dataclass(frozen=True)
class Int8Quant:
    """int8 + per-chunk float32 scale, stochastic rounding: E[q * scale] = v,
    so composition with any unbiased sparsifier stays unbiased."""

    role: ClassVar[str] = "quantize"
    name: ClassVar[str] = "int8"

    def encode(self, qkey, arrays: dict, value_names) -> dict:
        out = {}
        for n, v in arrays.items():
            if n not in value_names:
                out[n] = v
                continue
            scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0 + 1e-12
            u = jax.random.uniform(jax.random.fold_in(qkey, _salt(n)), v.shape)
            q = jnp.floor(v / scale + u)  # stochastic rounding
            out[n] = jnp.clip(q, -128, 127).astype(jnp.int8)
            out[n + "_scale"] = scale.astype(jnp.float32)
        return out

    def decode(self, arrays: dict, value_names) -> dict:
        out = {}
        for n, v in arrays.items():
            if n.endswith("_scale"):
                continue
            if n in value_names:
                out[n] = v.astype(jnp.float32) * arrays[n + "_scale"]
            else:
                out[n] = v
        return out

    def transform_schema(self, schema: tuple) -> tuple:
        out = []
        for s in schema:
            if s.kind != VALUES:
                out.append(s)
                continue
            out.append(s._replace(dtype="int8"))
            out.append(
                ArraySpec(s.name + "_scale", s.shape[:-1] + (1,), "float32", SCALES)
            )
        return tuple(out)


# golden-ratio low-discrepancy rotation of the dither grid (Suresh et al.
# 2022, arXiv:2203.04925): client i's rounding offset frac((i+1) * phi) is
# maximally spread over [0, 1) for every cohort prefix, with no dependence on
# the cohort size or the client's rank in it — so every re-derivation path
# (rho measurement, stale decode, the dist memory mirror) reproduces the
# exact encode bits from (round_key, client_id) alone.
_PHI = 0.6180339887498949
# fold_in tag separating the cohort-shared dither stream from the per-client
# qkey stream (client ids are small ints; this is far outside that range)
_COHORT_SALT = 0x0C011EC7


@dataclasses.dataclass(frozen=True)
class CorrelatedQuant(Int8Quant):
    """Correlated int8 quantization (Suresh et al. 2022): same wire format as
    ``Int8Quant`` (int8 values + per-chunk float32 scale, byte-identical
    ledger), but the stochastic-rounding dither is SHARED across the cohort —
    one uniform draw from the round key — and each client rotates it by a
    golden-ratio offset ``frac((client_id + 1) * phi)``.

    Each client's dither stays marginally U[0, 1) (a constant shift mod 1),
    so every unbiased sparsifier x CorrelatedQuant composition stays unbiased
    per client exactly as with Int8Quant. Where clients quantize the SAME
    coordinate at the same dither position (full-vector DME — the identity
    sparsifier — or any shared-support codec) the rounding errors
    anti-correlate: the offsets stratify [0, 1), so the SUM of the rounding
    errors concentrates instead of growing like sqrt(n) — strictly better
    mean-MSE than independent Int8 at equal bytes (gated continuously by
    ``bench_artifacts.py extract quant``). Composed with per-client supports
    (rand_k permutations, top-k selections) the dither positions never meet
    at an output coordinate, and CorrelatedQuant matches independent
    stochastic rounding instead of beating it; it never does worse.

    Needs cohort context: ``Pipeline.encode_payload`` threads the shared
    round key + client id in; constructing the dither from the per-client
    qkey alone would silently degenerate to independent rounding, so encoding
    without them raises instead.
    """

    role: ClassVar[str] = "quantize"
    name: ClassVar[str] = "correlated"
    needs_round_key: ClassVar[bool] = True

    def encode(self, qkey, arrays: dict, value_names, *, round_key=None,
               client_id=None) -> dict:
        if round_key is None or client_id is None:
            raise ValueError(
                "CorrelatedQuant needs the shared round key and the client id "
                "(anti-correlated dither is a cohort-level construction); "
                "encode through Pipeline.encode_payload / encode_all"
            )
        offset = jnp.mod(
            (jnp.asarray(client_id, jnp.float32) + 1.0) * _PHI, 1.0
        )
        dither_key = jax.random.fold_in(round_key, _COHORT_SALT)
        out = {}
        for n, v in arrays.items():
            if n not in value_names:
                out[n] = v
                continue
            scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0 + 1e-12
            shared = jax.random.uniform(
                jax.random.fold_in(dither_key, _salt(n)), v.shape
            )
            u = jnp.mod(shared + offset, 1.0)  # marginally U[0,1) per client
            q = jnp.floor(v / scale + u)
            out[n] = jnp.clip(q, -128, 127).astype(jnp.int8)
            out[n + "_scale"] = scale.astype(jnp.float32)
        return out


QUANTIZERS = {"bfloat16": Bf16Quant, "int8": Int8Quant,
              "correlated": CorrelatedQuant}
