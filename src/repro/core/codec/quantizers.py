"""Quantizer stages: payload-array transforms (paper §7 future work —
sparsification x quantization).

A quantizer never touches the vector domain: it rewrites the VALUES arrays
of an already-sparsified payload (indices, scales and aux stats pass
through), declares the resulting wire format via ``transform_schema``, and
inverts itself on the server (and inside ``self_decode``, so error feedback
sees exactly what the server reconstructs — the residual absorbs the
quantization error too).

``Int8Quant`` uses per-chunk max scales + STOCHASTIC rounding, so any
unbiased sparsifier composed with it stays unbiased (property-tested in
tests/test_codec_pipeline.py). Salts for the rounding noise are stable
per-array-name fold_in tags, identical to the historical ``payload_dtype``
path, so migrated pipelines are bit-compatible with the old spec.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import ClassVar

import jax
import jax.numpy as jnp

from .payload import SCALES, VALUES, ArraySpec

# stable fold_in tags (legacy payload_dtype="int8" parity)
_SALTS = {"vals": 101, "top_vals": 211, "rand_vals": 307}


def _salt(name: str) -> int:
    return _SALTS.get(name, int(zlib.crc32(name.encode()) & 0x7FFFFFF))


@dataclasses.dataclass(frozen=True)
class Bf16Quant:
    """bfloat16 cast of the value arrays: 2x fewer bytes, unbiased-in-
    expectation is NOT claimed (bf16 rounding is deterministic) but the error
    is tiny relative to sparsification noise."""

    role: ClassVar[str] = "quantize"
    name: ClassVar[str] = "bf16"

    def encode(self, qkey, arrays: dict, value_names) -> dict:
        return {
            n: (v.astype(jnp.bfloat16) if n in value_names else v)
            for n, v in arrays.items()
        }

    def decode(self, arrays: dict, value_names) -> dict:
        return {
            n: (v.astype(jnp.float32) if n in value_names else v)
            for n, v in arrays.items()
        }

    def transform_schema(self, schema: tuple) -> tuple:
        return tuple(
            s._replace(dtype="bfloat16") if s.kind == VALUES else s for s in schema
        )


@dataclasses.dataclass(frozen=True)
class Int8Quant:
    """int8 + per-chunk float32 scale, stochastic rounding: E[q * scale] = v,
    so composition with any unbiased sparsifier stays unbiased."""

    role: ClassVar[str] = "quantize"
    name: ClassVar[str] = "int8"

    def encode(self, qkey, arrays: dict, value_names) -> dict:
        out = {}
        for n, v in arrays.items():
            if n not in value_names:
                out[n] = v
                continue
            scale = jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0 + 1e-12
            u = jax.random.uniform(jax.random.fold_in(qkey, _salt(n)), v.shape)
            q = jnp.floor(v / scale + u)  # stochastic rounding
            out[n] = jnp.clip(q, -128, 127).astype(jnp.int8)
            out[n + "_scale"] = scale.astype(jnp.float32)
        return out

    def decode(self, arrays: dict, value_names) -> dict:
        out = {}
        for n, v in arrays.items():
            if n.endswith("_scale"):
                continue
            if n in value_names:
                out[n] = v.astype(jnp.float32) * arrays[n + "_scale"]
            else:
                out[n] = v
        return out

    def transform_schema(self, schema: tuple) -> tuple:
        out = []
        for s in schema:
            if s.kind != VALUES:
                out.append(s)
                continue
            out.append(s._replace(dtype="int8"))
            out.append(
                ArraySpec(s.name + "_scale", s.shape[:-1] + (1,), "float32", SCALES)
            )
        return tuple(out)


QUANTIZERS = {"bfloat16": Bf16Quant, "int8": Int8Quant}
