"""repro.core.codec — the composable codec pipeline (estimator API v2).

The paper's estimators are one point in a compression design space:
sparsification (Rand-k / SRHT projection), correlation-aware decoding
(spatial and temporal), and quantization. This package models that space as
orthogonal *stages* composed into a *pipeline*:

    from repro.core import codec
    pipe = codec.Pipeline([
        codec.RandProjSpatial(k=64, d_block=1024, transform="avg"),
        codec.Int8Quant(),
        codec.ErrorFeedback(),
    ])
    payload, _ = pipe.encode(key, client_id, x_chunks)
    x_hat = pipe.decode(key, stacked_payloads, n)

Payloads are self-describing (budget + exact declared byte ledger riding in
``payload.meta``); client-held cross-round state (EF residuals, temporal
memories) lives in an explicit ``ClientState`` pytree. ``build(name,
**old_kwargs)`` (see compat) keeps the historical flat-keyword construction
style working; the flat ``EstimatorSpec`` class itself is removed.
"""
from .budget import (  # noqa: F401
    BudgetExceedsDimension,
    adaptive_chunk_budgets,
    jl_min_k,
    suggest_budget,
)
from .compat import as_pipeline, build  # noqa: F401
from .entropy import EntropyCode, coded_payload_nbytes  # noqa: F401
from .payload import (  # noqa: F401
    AUX,
    INDICES,
    SCALES,
    VALUES,
    ArraySpec,
    Payload,
    PayloadMeta,
    check_against_schema,
    with_staleness,
)
from .pipeline import Pipeline  # noqa: F401
from .quantizers import (  # noqa: F401
    QUANTIZERS,
    Bf16Quant,
    CorrelatedQuant,
    Int8Quant,
)
from .sparsifiers import (  # noqa: F401
    SPARSIFIERS,
    Identity,
    Induced,
    RandK,
    RandKSpatial,
    RandProjSpatial,
    Sparsifier,
    SparseProj,
    TopK,
    Wangni,
)
from .stages import ClientState, ErrorFeedback, Temporal  # noqa: F401
