"""Budget auto-picker: the Johnson-Lindenstrauss bound as a k-from-(n, eps)
rule (Konecny & Richtarik's budget-selection problem, sklearn's
``johnson_lindenstrauss_min_dim`` closed form).

A random projection to k dimensions preserves pairwise distances among n
points to within a (1 ± eps) factor w.h.p. once

    k >= 4 ln(n) / (eps^2 / 2 - eps^3 / 3)

so for distributed mean estimation over ``n_clients`` vectors, requesting
distortion ``eps`` pins the per-chunk budget. ``fl.run --budget auto`` wires
this as the CLI entry point.
"""
from __future__ import annotations

import math


class BudgetExceedsDimension(ValueError):
    """The JL bound asks for more coordinates than the chunk has — the
    requested distortion is unattainable by projecting down; loosen ``eps``,
    shrink the cohort, or send the chunk uncompressed."""


def jl_min_k(n_clients: int, eps: float) -> int:
    """Closed-form JL lower bound on the projection dimension (no clamping)."""
    if not 0.0 < eps < 1.0:
        raise ValueError(f"eps must be in (0, 1), got {eps}")
    if n_clients < 2:
        raise ValueError(f"need n_clients >= 2 for a pairwise bound, got {n_clients}")
    denom = eps**2 / 2.0 - eps**3 / 3.0
    return int(math.ceil(4.0 * math.log(n_clients) / denom))


def suggest_budget(n_clients: int, eps: float, d: int) -> int:
    """Per-chunk budget k for ``n_clients`` vectors at JL distortion ``eps``.

    Monotone: non-decreasing in ``n_clients``, non-increasing in ``eps``.
    Raises :class:`BudgetExceedsDimension` when the bound exceeds ``d`` —
    silently clamping to d would report a distortion guarantee the budget
    cannot deliver.
    """
    if d < 1:
        raise ValueError(f"d must be >= 1, got {d}")
    k = jl_min_k(n_clients, eps)
    if k > d:
        raise BudgetExceedsDimension(
            f"JL bound needs k={k} coordinates for n_clients={n_clients} at "
            f"eps={eps}, but the chunk only has d={d}; loosen eps (>= "
            f"{_min_feasible_eps(n_clients, d):.3f} suffices) or send "
            "uncompressed"
        )
    return k


def _min_feasible_eps(n_clients: int, d: int, tol: float = 1e-3) -> float:
    """Smallest eps (to ``tol``) whose JL bound fits in d — for the error
    message's actionable hint; bisection on the monotone bound."""
    lo, hi = tol, 1.0 - tol
    if jl_min_k(n_clients, hi) > d:
        return hi
    while hi - lo > tol:
        mid = (lo + hi) / 2.0
        if jl_min_k(n_clients, mid) > d:
            lo = mid
        else:
            hi = mid
    return hi
