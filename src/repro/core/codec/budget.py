"""Budget auto-picker: the Johnson-Lindenstrauss bound as a k-from-(n, eps)
rule (Konecny & Richtarik's budget-selection problem, sklearn's
``johnson_lindenstrauss_min_dim`` closed form).

A random projection to k dimensions preserves pairwise distances among n
points to within a (1 ± eps) factor w.h.p. once

    k >= 4 ln(n) / (eps^2 / 2 - eps^3 / 3)

so for distributed mean estimation over ``n_clients`` vectors, requesting
distortion ``eps`` pins the per-chunk budget. ``fl.run --budget auto`` wires
this as the CLI entry point.
``adaptive_chunk_budgets`` is the other budget rule in this module: given a
fixed TOTAL budget ``C * k``, reallocate it across the C chunks proportional
to per-chunk norm mass (largest-remainder rounding, every chunk in
[1, d_block]) — the per-chunk adaptive budgets ``RoundConfig(
adaptive_budgets=True)`` derives each round from the server's previous mean.
Conservation ``sum(k_c) == C * k`` makes it a pure reallocation: wire bytes
are unchanged, only where they are spent moves.
"""
from __future__ import annotations

import math

import numpy as np


class BudgetExceedsDimension(ValueError):
    """The JL bound asks for more coordinates than the chunk has — the
    requested distortion is unattainable by projecting down; loosen ``eps``,
    shrink the cohort, or send the chunk uncompressed."""


def jl_min_k(n_clients: int, eps: float) -> int:
    """Closed-form JL lower bound on the projection dimension (no clamping)."""
    if not 0.0 < eps < 1.0:
        raise ValueError(f"eps must be in (0, 1), got {eps}")
    if n_clients < 2:
        raise ValueError(f"need n_clients >= 2 for a pairwise bound, got {n_clients}")
    denom = eps**2 / 2.0 - eps**3 / 3.0
    return int(math.ceil(4.0 * math.log(n_clients) / denom))


def suggest_budget(n_clients: int, eps: float, d: int) -> int:
    """Per-chunk budget k for ``n_clients`` vectors at JL distortion ``eps``.

    Monotone: non-decreasing in ``n_clients``, non-increasing in ``eps``.
    Raises :class:`BudgetExceedsDimension` when the bound exceeds ``d`` —
    silently clamping to d would report a distortion guarantee the budget
    cannot deliver.
    """
    if d < 1:
        raise ValueError(f"d must be >= 1, got {d}")
    k = jl_min_k(n_clients, eps)
    if k > d:
        prefix = (
            f"JL bound needs k={k} coordinates for n_clients={n_clients} at "
            f"eps={eps}, but the chunk only has d={d}; "
        )
        feasible = _min_feasible_eps(n_clients, d)
        if feasible is None:
            # even eps -> 1 does not fit: no amount of loosening helps, so do
            # not hint a fake threshold (the old message said ">= 0.999
            # suffices", which was false)
            raise BudgetExceedsDimension(
                prefix + "no eps in (0, 1) fits this (n_clients, d) — shrink "
                "the cohort or send uncompressed"
            )
        raise BudgetExceedsDimension(
            prefix + f"loosen eps (>= {feasible:.3f} suffices) or send "
            "uncompressed"
        )
    return k


def _min_feasible_eps(n_clients: int, d: int, tol: float = 1e-3) -> float | None:
    """Smallest eps (to ``tol``) whose JL bound fits in d — for the error
    message's actionable hint; bisection on the monotone bound. Returns None
    when NO eps in (0, 1) fits (``jl_min_k(n, 1 - tol) > d``) so the caller
    does not hint an eps that cannot work."""
    lo, hi = tol, 1.0 - tol
    if jl_min_k(n_clients, hi) > d:
        return None
    while hi - lo > tol:
        mid = (lo + hi) / 2.0
        if jl_min_k(n_clients, mid) > d:
            lo = mid
        else:
            hi = mid
    return hi


def adaptive_chunk_budgets(norm_mass, k: int, d_block: int) -> tuple:
    """Per-chunk budgets ``(k_0, ..., k_{C-1})`` proportional to norm mass.

    Splits the fixed total ``C * k`` across chunks with quota
    ``total * mass_c / sum(mass)``, rounded by largest remainder so the
    total is conserved EXACTLY, with every chunk clamped into
    ``[1, d_block]`` (a chunk never goes dark, never exceeds its dimension).
    Zero/degenerate mass falls back to the uniform allocation. Deterministic
    pure-host arithmetic: both sides of the wire derive the identical tuple
    from the shared previous-round mean.
    """
    mass = np.asarray(norm_mass, dtype=np.float64).ravel()
    c = int(mass.size)
    if c == 0:
        raise ValueError("need at least one chunk to allocate budgets over")
    if not 1 <= k <= d_block:
        raise ValueError(f"need 1 <= k <= d_block, got k={k}, d_block={d_block}")
    total = c * k
    if not np.all(np.isfinite(mass)) or np.any(mass < 0) or mass.sum() <= 0:
        return (k,) * c
    quota = np.clip(total * mass / mass.sum(), 1.0, float(d_block))
    base = np.clip(np.floor(quota).astype(np.int64), 1, d_block)
    rem = total - int(base.sum())
    frac = quota - np.floor(quota)
    if rem > 0:
        for j in np.argsort(-frac, kind="stable").tolist() * total:
            if rem == 0:
                break
            if base[j] < d_block:
                base[j] += 1
                rem -= 1
    elif rem < 0:
        for j in np.argsort(frac, kind="stable").tolist() * total:
            if rem == 0:
                break
            if base[j] > 1:
                base[j] -= 1
                rem += 1
    return tuple(int(b) for b in base)
