"""Normalisation constant beta_bar calibration.

Unbiasedness of the family estimator x_hat = (beta/n) (T(S))^dagger sum_i
G_i^T G_i x_i requires (paper App. B.1, and our docs/DESIGN.md §3.4)

    E[ (T(S))^dagger G_i^T G_i ] = (1/beta) I   for every client i
    =>  beta = n d / E[ tr( (T(S))^dagger S ) ]
            = n d / E[ sum_{lambda_j > 0} lambda_j / T(lambda_j) ]

where lambda_j are the eigenvalues of S (equivalently of the nk x nk Gram
matrix A A^T). The paper estimates beta by Monte-Carlo over 1000 runs; we do
the same but (a) jit+vmap the simulation, (b) cache an *eigenvalue bank*
(trials, nk) on disk keyed by (n, k, d), so that beta(rho) for ANY rho is a
cheap in-graph reduction over the bank — this is what makes the online
R-estimation mode (r_mode="est") free, since T_rho only reweights the same
cached eigenvalues.

Closed forms used as fast paths / test oracles:
  rho = 0 (T == 1):  tr(S) = nk exactly (SRHT rows are unit norm)  => beta = d/k.
  rho = 1 (T = id):  sum lambda/T(lambda) = rank(S) ~= nk w.h.p.   => beta ~= d/(nk).

For Rand-k-Spatial the law of the hit-count M_j is Binomial and beta has an
exact expression (no MC): beta = 1 / (p * E[1/T(1+B)]), B ~ Bin(n-1, p),
p = k/d. `rand_k_spatial_beta_weights` returns the pmf so the expectation is
an exact in-graph dot product (again differentiable in rho).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from scipy.stats import binom

from ..kernels import ops as kops
from . import transforms

_CACHE_DIR = os.environ.get(
    "REPRO_BETA_CACHE", os.path.join(os.path.dirname(__file__), "..", "..", "..", ".cache", "beta")
)


def default_trials(n: int, k: int) -> int:
    nk = n * k
    return int(max(64, min(512, (1 << 18) // max(nk, 1))))


def _bank_path(n: int, k: int, d: int, trials: int, seed: int, projection: str) -> str:
    os.makedirs(_CACHE_DIR, exist_ok=True)
    return os.path.join(
        _CACHE_DIR, f"{projection}_eigs_n{n}_k{k}_d{d}_t{trials}_s{seed}.npz"
    )


def _simulate_bank(
    n: int, k: int, d: int, trials: int, seed: int, projection: str
) -> np.ndarray:
    """Sample eigenvalues of A A^T, A = stack of n random (k x d) maps.

    May be invoked at trace time (beta is a compile-time constant of the
    decode graph), so force eager compile-time evaluation.
    """

    def one(key):
        keys = jax.random.split(key, n)

        def client(ck):
            k1, k2 = jax.random.split(ck)
            if projection == "srht":
                signs = jax.random.rademacher(k1, (d,), jnp.float32)
                rows = jax.random.permutation(k2, d)[:k]
                return kops.srht_rows_matrix(signs, rows, d)
            if projection == "gauss":
                return jax.random.normal(k1, (k, d)) / jnp.sqrt(d)
            if projection.startswith("sparse"):
                # very-sparse maps (SparseProj): nnz signed entries of
                # magnitude 1/sqrt(nnz) per row, columns WITH replacement
                # (scatter-ADD merges within-row duplicates) — the same law
                # as sparse_proj._client_draw, so the bank's eigenvalue
                # distribution matches the decode's S.
                nnz = int(projection[len("sparse"):])
                cols = jax.random.randint(k2, (k, nnz), 0, d)
                signs = jax.random.rademacher(k1, (k, nnz), jnp.float32)
                g = jnp.zeros((k, d), jnp.float32)
                g = g.at[jnp.arange(k)[:, None], cols].add(signs)
                return g * (1.0 / jnp.sqrt(1.0 * nnz))
            raise ValueError(f"no eig bank for projection {projection!r}")

        a = jax.vmap(client)(keys).reshape(n * k, d)
        gram = a @ a.T
        return jnp.linalg.eigvalsh(gram)

    with jax.ensure_compile_time_eval():
        keys = jax.random.split(jax.random.key(seed), trials)
        # batch to bound memory for large (nk, d)
        bs = max(1, min(trials, (1 << 24) // (n * k * d)))
        outs = []
        fn = jax.vmap(one)
        for i in range(0, trials, bs):
            outs.append(np.asarray(fn(keys[i : i + bs])))
    return np.concatenate(outs, axis=0)


@functools.lru_cache(maxsize=64)
def srht_eig_bank(
    n: int, k: int, d: int, trials: int | None = None, seed: int = 0,
    projection: str = "srht",
) -> np.ndarray:
    """(trials, nk) eigenvalue bank for S with n random-map clients; disk-cached."""
    trials = trials or default_trials(n, k)
    path = _bank_path(n, k, d, trials, seed, projection)
    if os.path.exists(path):
        return np.load(path)["eigs"]
    eigs = _simulate_bank(n, k, d, trials, seed, projection)
    np.savez_compressed(path, eigs=eigs)
    return eigs


def sparse_eig_bank(
    n: int, k: int, d: int, nnz: int, trials: int | None = None, seed: int = 0
) -> np.ndarray:
    """Eigenvalue bank for SparseProj's S — same machinery as the SRHT bank,
    keyed (and disk-cached) by the per-row density ``nnz`` as well, since the
    spectrum of S depends on how sparse the maps are."""
    if not 1 <= nnz <= d:
        raise ValueError(f"nnz must be in [1, d={d}], got {nnz}")
    return srht_eig_bank(n, k, d, trials, seed, projection=f"sparse{nnz}")


def beta_fn_from_bank(bank: np.ndarray, n: int, d: int, eps: float = 0.0):
    """-> callable rho -> beta (jnp, differentiable; rho may be traced).

    With ``eps > 0`` the constant calibrates the RIDGE-filtered estimator
    x_hat = (beta_eps/n) (T(S) + eps I)^{-1} y used by the fused CG decode
    (docs/DESIGN.md §3.5): the same isotropy argument applies verbatim to
    T_eps(lambda) = T(lambda) + eps, so unbiasedness is exact, not
    approximate. Because T_eps is bounded away from zero the spectral
    floor used at eps == 0 to emulate the pseudo-inverse is dropped —
    near-zero bank eigenvalues self-suppress via lambda / (T(lambda) + eps).
    """
    bank_j = jnp.asarray(bank)

    def beta(rho):
        t = transforms.t_apply(bank_j, rho) + eps
        if eps > 0.0:
            contrib = jnp.maximum(bank_j, 0.0) / t
        else:
            contrib = jnp.where(bank_j > 1e-4, bank_j / t, 0.0)
        c = jnp.mean(jnp.sum(contrib, axis=-1)) / (n * d)
        return 1.0 / c

    return beta


def srht_beta(n: int, k: int, d: int, rho: float, trials: int | None = None, seed: int = 0) -> float:
    """Scalar beta_bar for Rand-Proj-Spatial(SRHT) with T_rho."""
    if rho == 0.0:
        return d / k  # exact: tr(S) = nk
    bank = srht_eig_bank(n, k, d, trials, seed)
    return float(beta_fn_from_bank(bank, n, d)(rho))


# ---------------------------------------------------------------- Rand-k-Spatial


@functools.lru_cache(maxsize=256)
def rand_k_spatial_beta_weights(n: int, k: int, d: int) -> tuple[float, np.ndarray]:
    """(p, pmf of B ~ Bin(n-1, p)) with p = k/d, in float64."""
    p = k / d
    b = np.arange(n)
    return p, binom.pmf(b, n - 1, p)


def rand_k_spatial_beta(n: int, k: int, d: int, rho) -> jnp.ndarray:
    """Exact beta = 1 / (p E[1/T(1+B)]); rho may be traced (in-graph)."""
    p, pmf = rand_k_spatial_beta_weights(n, k, d)
    m = jnp.asarray(1.0 + np.arange(n), jnp.float32)  # 1 + B
    inv_t = 1.0 / transforms.t_apply(m, rho)
    # multiply + row-sum rather than jnp.dot: a batched dot (this is vmapped
    # over per-chunk rho in r_mode="est") may pick a batch-shape-dependent
    # reduction, breaking the ownership slice-parity contract at 1 ulp.
    return 1.0 / (p * jnp.sum(jnp.asarray(pmf, jnp.float32) * inv_t, axis=-1))
