"""Eigenvalue transformation functions T (paper Eq. 11 and §4.1/4.2).

The whole Rand-*-Spatial family is parameterised by

    T(m) = 1 + rho * (m - 1),      rho = R / (n - 1)

where R in [-1, n-1] is the degree of cross-client correlation (Eq. 7):

    rho = 0                -> T == 1      (no-correlation optimum, Thm 4.4)
    rho = 1                -> T(m) = m    (full-correlation optimum, "Max", Thm 4.3)
    rho = (n/2)/(n-1)      -> the practical "Avg" interpolation (unknown R)
    rho = R/(n-1)          -> "Opt" for a known/estimated R

T is applied to coordinate hit-counts M_j in Rand-k-Spatial and to the
eigenvalues of S = sum_i G_i^T G_i in Rand-Proj-Spatial.
"""
from __future__ import annotations

import jax.numpy as jnp

VARIANTS = ("one", "max", "avg", "opt")
# "wavg" — the practical Rand-Proj-Spatial(wavg) variant — is a round-level
# policy, not a transform: the FL server tracks R online (EMA of r_exact over
# per-client reconstructions, repro.fl.server) and resolves wavg to
# opt(r_value=R_hat) by rewriting the pipeline's sparsifier config
# (resolve_pipeline), falling back to avg while no history exists. It must be
# resolved before the decode graph is built, hence not listed in VARIANTS.


def rho_for(transform: str, n: int, r_value=None):
    """Interpolation weight rho = R/(n-1) for a transform variant."""
    if transform == "one":
        return 0.0
    if transform == "max":
        return 1.0
    if transform == "avg":
        return (n / 2.0) / (n - 1.0)
    if transform == "opt":
        if r_value is None:
            raise ValueError("transform='opt' needs r_value (known or estimated R)")
        return r_value / (n - 1.0)
    if transform == "wavg":
        raise ValueError(
            "transform='wavg' is resolved by the FL server (repro.fl.server."
            "resolve_pipeline) into opt/avg before decode; it cannot be used "
            "directly in an estimator decode graph"
        )
    raise ValueError(f"unknown transform {transform!r}; pick from {VARIANTS}")


def clip_rho(rho, n: int):
    """Keep T positive on its support: rho in (-1/(n-1), 1]."""
    lo = -1.0 / (n - 1.0) * 0.999
    return jnp.clip(rho, lo, 1.0)


def t_apply(m, rho):
    """T(m) = 1 + rho (m - 1). Works on scalars and arrays; rho may be traced."""
    return 1.0 + rho * (m - 1.0)
