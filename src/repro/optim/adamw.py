"""Pure-JAX optimizers (no optax). States are pytrees mirroring params, so
they inherit the params' PartitionSpecs (ZeRO: optimizer state is FSDP-sharded
exactly like the parameters).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def schedule(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        return self.lr * warm

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        else:
            gnorm = global_norm(grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads
        )
        lr = self.schedule(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: float = 0.1
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - self.lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads,
            )
            return new_params, {"step": step}, {"grad_norm": global_norm(grads), "lr": self.lr}
        m = jax.tree.map(
            lambda mm, g: self.momentum * mm + g.astype(jnp.float32), state["m"], grads
        )
        new_params = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - self.lr * mm).astype(p.dtype), params, m
        )
        return new_params, {"m": m, "step": step}, {"grad_norm": global_norm(grads), "lr": self.lr}
