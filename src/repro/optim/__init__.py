from .adamw import AdamW, SGDM  # noqa: F401
