"""Training step factory: loss -> per-client grads -> (compressed) mean ->
optimizer.

Two modes:
  - dme_spec=None: standard GSPMD step; gradient reduction over all DP axes
    is the implicit (uncompressed) all-reduce. This is the roofline BASELINE.
  - dme_spec=<codec Pipeline | sparsifier config>:
    the batch carries a leading client axis (sharded over `client_axes`,
    default the 'pod' mesh axis). Per-client grads come from vmap (no
    cross-client reduction is ever materialised); the cross-client mean is
    the paper's estimator via dist.collectives.compressed_mean_tree. In-pod
    reduction (the 'data' axis inside each client slice) stays an
    uncompressed fast-ICI psum.

Error feedback (an ErrorFeedback stage in the pipeline, Top-k-style biased
codecs): a per-client residual buffer lives in train_state["ef"], added to
the gradient before encoding and rebuilt from the pipeline's self-decode
after.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.codec import as_pipeline
from ..dist import collectives
from ..models import transformer


def _loss(params, cfg, batch):
    return transformer.loss_fn(params, cfg, batch)


def init_train_state(cfg, optimizer, params, dme_spec=None, n_clients: int = 0):
    state = {"opt": optimizer.init(params)}
    if dme_spec is not None:
        pipe = as_pipeline(dme_spec)
        if pipe.has_ef:
            from jax.flatten_util import ravel_pytree

            from ..core import chunking

            d_flat = ravel_pytree(params)[0].shape[0]
            c = chunking.num_chunks(d_flat, pipe.d_block)
            state["ef"] = jnp.zeros((n_clients, c, pipe.d_block), jnp.float32)
    return state


def make_train_step(cfg, optimizer, *, dme_spec=None, mesh=None,
                    client_axes=("pod",), seed: int = 0, dme_impl: str = "auto",
                    dme_overlap: bool = False, dme_overlap_tile: int = 1,
                    dme_ownership=False):
    """``dme_overlap=True`` streams the gradient's chunk axis through the
    collectives' double buffer (encode chunk c+1 while chunk c's payload is
    in flight) — bit-identical to the synchronous exchange, so it composes
    with EF and both impls; requires a chunk-streamable pipeline.

    ``dme_ownership`` (True / owner count / ``dist.sharding.ChunkOwnership``)
    runs the server decode owner-partitioned (docs/DESIGN.md §10): on the
    shard_map impl each mesh shard receives and decodes only the gradient
    chunks it owns (all_to_all payload routing + one all_gather of decoded
    means) instead of materialising every client's payload; bit-identical to
    the replicated decode, composes with EF and ``dme_overlap``."""
    base_key = jax.random.key(seed)
    if dme_spec is not None:
        dme_spec = as_pipeline(dme_spec)
        if dme_overlap:
            collectives.check_streamable(dme_spec)
        if dme_ownership:
            collectives.check_shardable(dme_spec)

    if dme_spec is None:

        def plain_step(params, state, batch, step):
            (loss, metrics), grads = jax.value_and_grad(_loss, has_aux=True)(
                params, cfg, batch
            )
            params, opt, om = optimizer.update(grads, state["opt"], params)
            return params, {"opt": opt}, {"loss": loss, **metrics, **om}

        return plain_step

    # shard_map path: local chunking, payload-only cross-client traffic
    # (§Perf H-c). gspmd path kept as the measured baseline. EF residuals are
    # supported on both paths (shard_map keeps each row on its client shard).
    use_shardmap = mesh is not None and dme_impl in ("auto", "shard_map")
    shardings = collectives.dme_shardings(mesh, client_axes)
    param_pspecs = None
    if use_shardmap:
        from ..dist import sharding as shard_lib

        param_pspecs = jax.tree.map(
            lambda ns: ns.spec, shard_lib.param_shardings(cfg, mesh)
        )

    def dme_step(params, state, batch, step):
        key = jax.random.fold_in(base_key, step)

        def per_client(b):
            (l, m), g = jax.value_and_grad(_loss, has_aux=True)(params, cfg, b)
            return l, m, g

        losses, metrics, grads = jax.vmap(per_client)(batch)
        if use_shardmap:
            grad_mean, info, new_ef = collectives.compressed_mean_tree_shardmap(
                dme_spec, key, grads, mesh, param_pspecs, client_axes,
                ef_chunks=state.get("ef"),
                overlap=dme_overlap, overlap_tile=dme_overlap_tile,
                ownership=dme_ownership or None,
            )
        else:
            grad_mean, info, new_ef = collectives.compressed_mean_tree(
                dme_spec, key, grads, shardings, ef_chunks=state.get("ef"),
                overlap=dme_overlap, overlap_tile=dme_overlap_tile,
                ownership=dme_ownership or None,
            )
        params, opt, om = optimizer.update(grad_mean, state["opt"], params)
        new_state = {"opt": opt}
        if new_ef is not None:
            new_state["ef"] = new_ef
        out = {
            "loss": jnp.mean(losses),
            **{k: jnp.mean(v) for k, v in metrics.items()},
            **om,
            "compression_ratio": info["full_bytes"] / max(info["payload_bytes_per_client"], 1),
        }
        if dme_ownership:
            reduction = collectives.intra_pod_reduction(info)
            if reduction is not None:
                out["intra_pod_reduction"] = reduction
        return params, new_state, out

    return dme_step
