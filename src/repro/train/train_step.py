"""Training step factory: loss -> per-client grads -> (compressed) mean ->
optimizer.

Two modes:
  - dme_spec=None: standard GSPMD step; gradient reduction over all DP axes
    is the implicit (uncompressed) all-reduce. This is the roofline BASELINE.
  - dme_spec=<codec Pipeline | sparsifier config>:
    the batch carries a leading client axis (sharded over `client_axes`,
    default the 'pod' mesh axis). Per-client grads come from vmap (no
    cross-client reduction is ever materialised); the cross-client mean is
    the paper's estimator via dist.collectives.compressed_mean_tree. In-pod
    reduction (the 'data' axis inside each client slice) stays an
    uncompressed fast-ICI psum.

Error feedback (an ErrorFeedback stage in the pipeline, Top-k-style biased
codecs): a per-client residual buffer lives in train_state["ef"], added to
the gradient before encoding and rebuilt from the pipeline's self-decode
after.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.codec import as_pipeline
from ..dist import collectives
from ..models import transformer


def _loss(params, cfg, batch):
    return transformer.loss_fn(params, cfg, batch)


def _pipelined_loss(params, cfg, batch, *, mesh, axis, n_stages, n_micro):
    """``transformer.loss_fn`` with the scanned block stack run through
    ``dist.pipeline.pipeline_apply`` (GPipe over the ``axis`` mesh axis).

    Embed / prologue / epilogue / logits / CE are the exact expressions from
    ``loss_fn``; only the repeated block stack is staged. The batch is split
    into ``n_micro`` microbatches along the leading batch dim, so batch must
    divide evenly. MoE block patterns are rejected up front: the pipeline
    stage carries activations only, so the router aux loss from scanned
    blocks would be silently dropped (prologue/epilogue MoE is fine — those
    run unrolled outside the pipeline).
    """
    from ..dist import pipeline as pipe_lib
    from ..models import act_sharding

    inputs, labels = batch["inputs"], batch["labels"]
    b, s = inputs.shape[0], inputs.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = transformer.embed_inputs(params, cfg, inputs)
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.prologue):
        x, aux, _ = transformer._run_layer(
            cfg, spec, params["prologue"][i], x, aux, positions, None
        )

    if cfg.n_blocks > 0:

        def stage_fn(stage_params, h):
            pos = jnp.broadcast_to(
                jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2]
            )

            def body(carry, p_block):
                xx, _, _ = transformer._run_block(
                    cfg, p_block, carry, jnp.zeros((), jnp.float32), pos, None
                )
                return xx, None

            if cfg.remat == "block":
                body = jax.checkpoint(body)
            h, _ = jax.lax.scan(body, h, stage_params)
            return h

        staged = pipe_lib.partition_blocks(params["blocks"], n_stages)
        mb = b // n_micro
        # activation-sharding constraints don't compose with shard_map's
        # per-shard view; the pipeline manages placement itself
        with act_sharding.constraint(None):
            xm = x.reshape((n_micro, mb) + x.shape[1:])
            xm = pipe_lib.pipeline_apply(stage_fn, staged, xm, mesh, axis)
        x = xm.reshape((b,) + x.shape[1:])

    for i, spec in enumerate(cfg.epilogue):
        x, aux, _ = transformer._run_layer(
            cfg, spec, params["epilogue"][i], x, aux, positions, None
        )
    logits = transformer.logits_fn(params, cfg, x)
    valid = labels >= 0
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def init_train_state(cfg, optimizer, params, dme_spec=None, n_clients: int = 0):
    state = {"opt": optimizer.init(params)}
    if dme_spec is not None:
        pipe = as_pipeline(dme_spec)
        if pipe.has_ef:
            from jax.flatten_util import ravel_pytree

            from ..core import chunking

            d_flat = ravel_pytree(params)[0].shape[0]
            c = chunking.num_chunks(d_flat, pipe.d_block)
            state["ef"] = jnp.zeros((n_clients, c, pipe.d_block), jnp.float32)
    return state


def make_train_step(cfg, optimizer, *, dme_spec=None, mesh=None,
                    client_axes=("pod",), seed: int = 0, dme_impl: str = "auto",
                    dme_overlap: bool = False, dme_overlap_tile: int = 1,
                    dme_ownership=False, pipeline_stages: int = 0,
                    pipeline_axis: str = "pipe",
                    pipeline_microbatches: int = 0):
    """``dme_overlap=True`` streams the gradient's chunk axis through the
    collectives' double buffer (encode chunk c+1 while chunk c's payload is
    in flight) — bit-identical to the synchronous exchange, so it composes
    with EF and both impls; requires a chunk-streamable pipeline.

    ``dme_ownership`` (True / owner count / ``dist.sharding.ChunkOwnership``)
    runs the server decode owner-partitioned (docs/DESIGN.md §10): on the
    shard_map impl each mesh shard receives and decodes only the gradient
    chunks it owns (all_to_all payload routing + one all_gather of decoded
    means) instead of materialising every client's payload; bit-identical to
    the replicated decode, composes with EF and ``dme_overlap``.

    ``pipeline_stages >= 1`` runs the scanned block stack layer-pipelined
    over the ``pipeline_axis`` mesh axis (GPipe, ``dist.pipeline``) inside
    the loss; microbatch count defaults to the stage count. Composes with
    both dme paths (the pipeline shard_map lives inside the per-client
    vmapped loss)."""
    base_key = jax.random.key(seed)
    loss_fn = _loss
    if pipeline_stages:
        if mesh is None or pipeline_axis not in mesh.shape:
            raise ValueError(
                f"pipeline_stages={pipeline_stages} needs a mesh with a "
                f"'{pipeline_axis}' axis"
            )
        if mesh.shape[pipeline_axis] != pipeline_stages:
            raise ValueError(
                f"pipeline_stages={pipeline_stages} != mesh axis "
                f"'{pipeline_axis}' size {mesh.shape[pipeline_axis]}"
            )
        for spec in cfg.block_pattern:
            if spec.ffn == "moe":
                raise ValueError(
                    "pipeline_stages does not support MoE block patterns "
                    "(the stage hop would drop the router aux loss)"
                )
        loss_fn = functools.partial(
            _pipelined_loss, mesh=mesh, axis=pipeline_axis,
            n_stages=pipeline_stages,
            n_micro=pipeline_microbatches or pipeline_stages,
        )
    if dme_spec is not None:
        dme_spec = as_pipeline(dme_spec)
        if dme_overlap:
            collectives.check_streamable(dme_spec)
        if dme_ownership:
            collectives.check_shardable(dme_spec)

    if dme_spec is None:

        def plain_step(params, state, batch, step):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, cfg, batch
            )
            params, opt, om = optimizer.update(grads, state["opt"], params)
            return params, {"opt": opt}, {"loss": loss, **metrics, **om}

        return plain_step

    # shard_map path: local chunking, payload-only cross-client traffic
    # (§Perf H-c). gspmd path kept as the measured baseline. EF residuals are
    # supported on both paths (shard_map keeps each row on its client shard).
    use_shardmap = (
        mesh is not None and dme_impl in ("auto", "shard_map")
        and all(ax in mesh.shape for ax in client_axes)
    )
    shardings = collectives.dme_shardings(mesh, client_axes)
    param_pspecs = None
    if use_shardmap:
        from ..dist import sharding as shard_lib

        param_pspecs = jax.tree.map(
            lambda ns: ns.spec, shard_lib.param_shardings(cfg, mesh)
        )

    def dme_step(params, state, batch, step):
        key = jax.random.fold_in(base_key, step)

        def per_client(b):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, b)
            return l, m, g

        losses, metrics, grads = jax.vmap(per_client)(batch)
        if use_shardmap:
            grad_mean, info, new_ef = collectives.compressed_mean_tree_shardmap(
                dme_spec, key, grads, mesh, param_pspecs, client_axes,
                ef_chunks=state.get("ef"),
                overlap=dme_overlap, overlap_tile=dme_overlap_tile,
                ownership=dme_ownership or None,
            )
        else:
            grad_mean, info, new_ef = collectives.compressed_mean_tree(
                dme_spec, key, grads, shardings, ef_chunks=state.get("ef"),
                overlap=dme_overlap, overlap_tile=dme_overlap_tile,
                ownership=dme_ownership or None,
            )
        params, opt, om = optimizer.update(grad_mean, state["opt"], params)
        new_state = {"opt": opt}
        if new_ef is not None:
            new_state["ef"] = new_ef
        out = {
            "loss": jnp.mean(losses),
            **{k: jnp.mean(v) for k, v in metrics.items()},
            **om,
            "compression_ratio": info["full_bytes"] / max(info["payload_bytes_per_client"], 1),
        }
        if dme_ownership:
            reduction = collectives.intra_pod_reduction(info)
            if reduction is not None:
                out["intra_pod_reduction"] = reduction
        return params, new_state, out

    return dme_step
