"""Serving steps: prefill (builds cache) and single-token decode.

The paper's technique targets gradient aggregation, so serve steps carry no
DME compression (noted per-cell in docs/EXPERIMENTS.md). The decode step with a
sequence-sharded cache relies on GSPMD partitioning the softmax reductions
over the sharded KV length (partial max/sum + all-reduce — flash-decode
combine without hand-written collectives).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..models import transformer


def make_decode_step(cfg):
    def decode_step(params, cache, tokens, positions):
        logits, new_cache = transformer.decode_step(params, cfg, cache, tokens, positions)
        next_token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        return next_token, logits, new_cache

    return decode_step


def make_prefill_step(cfg):
    def prefill(params, cache, tokens):
        return transformer.prefill(params, cfg, cache, tokens)

    return prefill
