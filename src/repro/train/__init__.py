from .train_step import make_train_step, init_train_state  # noqa: F401
from .serve_step import make_decode_step, make_prefill_step  # noqa: F401
