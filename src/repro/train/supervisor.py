"""Fault-tolerant training supervisor.

Production behaviours, exercised on one host via deterministic fault
injection (tests/test_fault_tolerance.py):

- restart-on-failure: any step exception -> restore latest checkpoint and
  continue (data pipeline is a pure function of step, so no data loss).
- elastic client count: the DME estimator depends on n only through
  beta(n, k, d, T); on pod loss/join the supervisor rebuilds the train step
  with the new n and keeps going from the same checkpoint (params are
  client-count independent). Unbiasedness is preserved per round.
- straggler mitigation: a round may drop clients (bounded staleness); the
  decode re-normalises with beta(n_eff) — the estimator stays unbiased over
  the surviving set. Modeled by re-building the step for n_eff.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from . import checkpoint as ckpt_lib


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection for tests/demos."""
    fail_at_steps: tuple[int, ...] = ()        # raise before these steps once
    resize_at: dict | None = None              # {step: new_n_clients}

    def __post_init__(self):
        self._fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")

    def resize(self, step: int):
        if self.resize_at:
            return self.resize_at.get(step)
        return None


@dataclasses.dataclass
class Supervisor:
    make_step: Callable[[int], Callable]   # n_clients -> jitted step fn
    make_data: Callable[[int], Callable]   # n_clients -> (step -> batch)
    init_state: Callable[[], tuple]        # () -> (params, state)
    ckpt_dir: str
    n_clients: int
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 10

    def run(self, total_steps: int, fault_plan: FaultPlan | None = None,
            log_every: int = 10, log_fn=print):
        fault_plan = fault_plan or FaultPlan()
        ckptr = ckpt_lib.AsyncCheckpointer(self.ckpt_dir, keep=self.keep)
        params, state = self.init_state()
        start = 0
        if ckpt_lib.latest_step(self.ckpt_dir) is not None:
            (params, state), start = ckpt_lib.restore(self.ckpt_dir, (params, state))
            start += 1
            log_fn(f"[supervisor] resumed from step {start - 1}")
        step_fn = self.make_step(self.n_clients)
        data_fn = self.make_data(self.n_clients)
        restarts = 0
        history = []
        step = start
        while step < total_steps:
            try:
                new_n = fault_plan.resize(step)
                if new_n is not None and new_n != self.n_clients:
                    log_fn(f"[supervisor] elastic resize {self.n_clients} -> {new_n} at step {step}")
                    self.n_clients = new_n
                    step_fn = self.make_step(new_n)
                    data_fn = self.make_data(new_n)
                fault_plan.maybe_fail(step)
                batch = data_fn(step)
                t0 = time.time()
                params, state, metrics = step_fn(params, state, batch, step)
                if step % log_every == 0:
                    loss = float(metrics["loss"])
                    history.append((step, loss))
                    log_fn(f"[step {step}] loss={loss:.4f} ({time.time()-t0:.2f}s)")
                if self.ckpt_every and step % self.ckpt_every == 0 and step > start:
                    ckptr.save_async(step, (params, state))
                step += 1
            except Exception as e:  # noqa: BLE001 — restart-on-any-failure
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                log_fn(f"[supervisor] step {step} failed ({e}); restoring...")
                ckptr.wait()
                last = ckpt_lib.latest_step(self.ckpt_dir)
                if last is not None:
                    (params, state), last = ckpt_lib.restore(self.ckpt_dir, (params, state))
                    step = last + 1
                else:
                    params, state = self.init_state()
                    step = 0
                step_fn = self.make_step(self.n_clients)
                data_fn = self.make_data(self.n_clients)
        ckptr.wait()
        ckpt_lib.save(self.ckpt_dir, total_steps - 1, (params, state), keep=self.keep)
        return params, state, history
