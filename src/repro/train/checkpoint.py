"""Fault-tolerant checkpointing: atomic, sharded, async, keep-N, resumable.

Layout (one directory per step):
    <dir>/step_000120.tmp_<nonce>/   -> written, fsynced, then atomically
    <dir>/step_000120/                  renamed; readers only ever see
        meta.msgpack                    complete checkpoints.
        shard_00000.npz                 leaves partitioned into ~512MB shards
        ...

- Pytree structure + leaf metadata travel in meta.msgpack; arrays in npz
  shards, so a checkpoint restores on a different mesh/host layout
  (elastic restart) — sharding is re-applied by the caller via
  jax.device_put with the new shardings.
- `save_async` runs serialization on a background thread with a copy-on-host
  snapshot so the train loop continues immediately.
- `latest_step`/`restore` skip corrupt/partial directories (crash-safe).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import uuid

import jax
import ml_dtypes
import msgpack
import numpy as np

_SHARD_BYTES = 512 << 20
_STEP_RE = re.compile(r"^step_(\d+)$")

# npz can't serialise ml_dtypes; round-trip them through bit-equal views.
_CUSTOM_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


def _to_native(a: np.ndarray) -> np.ndarray:
    name = str(a.dtype)
    if name in _CUSTOM_DTYPES:
        return a.view(_CUSTOM_DTYPES[name][0])
    return a


def _from_native(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _CUSTOM_DTYPES:
        return a.view(_CUSTOM_DTYPES[dtype_name][1])
    return a


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    leaves, treedef = _flatten(tree)
    host = [np.asarray(l) for l in leaves]
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = final + f".tmp_{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)

    shards, cur, cur_bytes = [], [], 0
    for i, arr in enumerate(host):
        cur.append(i)
        cur_bytes += arr.nbytes
        if cur_bytes >= _SHARD_BYTES:
            shards.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        shards.append(cur)

    leaf_meta = [None] * len(host)
    for si, idxs in enumerate(shards):
        for i in idxs:
            leaf_meta[i] = {
                "shape": list(host[i].shape), "dtype": str(host[i].dtype), "shard": si
            }
    meta = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host),
        "leaves": leaf_meta,
        "shards": len(shards),
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    for si, idxs in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{si:05d}.npz"),
                 **{f"leaf_{i}": _to_native(host[i]) for i in idxs})
    if os.path.exists(final):
        # a complete checkpoint for this step was already published
        shutil.rmtree(tmp, ignore_errors=True)
    else:
        os.replace(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Snapshot-on-call, serialize-on-thread. wait() joins the last save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree):
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]  # device->host snapshot now
        snap = jax.tree_util.tree_unflatten(treedef, host)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, snap), kwargs={"keep": self.keep}
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    s = steps(ckpt_dir)
    return s[-1] if s else None


def restore(ckpt_dir: str, tree_like, step: int | None = None, *, shardings=None):
    """Restore into the structure of `tree_like`. Optionally device_put with
    `shardings` (same pytree structure) for elastic re-mesh restores."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    leaves_like, treedef = _flatten(tree_like)
    if meta["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, target tree {len(leaves_like)}"
        )
    host: list[np.ndarray | None] = [None] * meta["n_leaves"]
    for si in range(meta["shards"]):
        with np.load(os.path.join(path, f"shard_{si:05d}.npz")) as z:
            for name in z.files:
                i = int(name.split("_")[1])
                host[i] = _from_native(z[name], meta["leaves"][i]["dtype"])
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set") or x is None
        )
        host = [
            jax.device_put(a, s) if s is not None else a
            for a, s in zip(host, sh_leaves)
        ]
    return jax.tree_util.tree_unflatten(treedef, host), step


def _gc(ckpt_dir: str, keep: int):
    all_steps = steps(ckpt_dir)
    for s in all_steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:06d}"), ignore_errors=True)
    # clean stale tmp dirs from crashed writers
    for name in os.listdir(ckpt_dir):
        if ".tmp_" in name:
            full = os.path.join(ckpt_dir, name)
            if os.path.isdir(full):
                shutil.rmtree(full, ignore_errors=True)
