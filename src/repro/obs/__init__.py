"""repro.obs — metrics registry, round-timeline tracing, profiling hooks.

The observability layer for the codec -> collectives -> FL stack
(docs/OBSERVABILITY.md). Everything is OFF by default and the disabled
paths are one-flag-check no-ops, so an uninstrumented run is bitwise
identical to pre-instrumentation behaviour (tests/test_obs.py).

    from repro import obs

    obs.enable()                                  # metrics on
    tracer = obs.install_tracer(obs.Tracer())     # + round timeline
    ...run rounds...
    tracer.write("trace.json")                    # Perfetto-loadable
    print(obs.snapshot()["counters"])             # flat metrics export

Three submodules:

- ``registry`` — counters/gauges/histograms keyed ``component/name``,
  recording ``span``s and zero-duration ``marker``s; jit-tracer-safe.
- ``trace``    — Chrome-trace/Perfetto event collection, one track per
  round phase; ``install_tracer`` makes it the process emission target.
- ``profile``  — ``jax.profiler`` session wiring + kernel dispatch / CG /
  compile-time telemetry hooks.
"""
from .profile import (  # noqa: F401
    profiler_session,
    record_cg_iters,
    record_compile,
    record_decode_route,
    record_dispatch,
)
from .registry import (  # noqa: F401
    count,
    disable,
    enable,
    enabled,
    gauge,
    marker,
    observe,
    reset,
    snapshot,
    span,
    tracer_drops,
)
from .trace import (  # noqa: F401
    PHASES,
    Tracer,
    current_tracer,
    install_tracer,
    now_us,
    uninstall_tracer,
)
