"""Profiling hooks: ``jax.profiler`` session wiring + kernel telemetry.

Two kinds of hook live here, both no-ops unless explicitly requested:

- ``profiler_session(logdir)``: context manager around
  ``jax.profiler.start_trace`` / ``stop_trace``. The resulting TensorBoard/
  Perfetto-XL profile is the *device*-level view (XLA ops, fusion, HBM);
  the ``repro.obs.trace`` round tracer is the *system*-level view (phases,
  bytes). Wired to ``repro.fl.run --profile-dir``. Degrades to a plain
  pass-through (with one warning) where the profiler is unavailable —
  profiling is observability, never a hard dependency.

- kernel-dispatch telemetry: ``record_dispatch`` (which route
  ``kernels.ops._should_use_pallas`` took per op), ``record_decode_route``
  (fused / gram / direct per rand_proj_spatial decode), and
  ``record_cg_iters`` (iterations the fused resolvent CG actually ran).
  Dispatch decisions are Python-level statics, so they record under jit
  (once per trace — i.e. per compilation); CG iterations are data-dependent
  and therefore recorded only on eager executions (under jit the sample is
  a tracer and the registry drops it — the tracer-safety contract).
"""
from __future__ import annotations

import contextlib
import warnings

from . import registry


@contextlib.contextmanager
def profiler_session(logdir: str | None):
    """Wrap a block in a ``jax.profiler`` trace writing to ``logdir``; a
    None logdir (or an unavailable profiler) is a pass-through."""
    if logdir is None:
        yield
        return
    import jax

    try:
        jax.profiler.start_trace(logdir)
    except Exception as e:  # profiler backends vary by install
        warnings.warn(f"jax.profiler unavailable ({e}); continuing unprofiled")
        yield
        return
    try:
        yield
    finally:
        jax.profiler.stop_trace()


def record_dispatch(op: str, use_kernel: bool, interpret: bool) -> None:
    """Count one ``_should_use_pallas`` decision for ``op``."""
    if not registry.enabled():
        return
    route = ("pallas_interpret" if use_kernel and interpret
             else "pallas" if use_kernel else "oracle")
    registry.count("kernels", "dispatch", op=op, route=route)


def record_decode_route(estimator: str, method: str) -> None:
    """Count the decode path a spatial estimator resolved to."""
    if not registry.enabled():
        return
    registry.count("kernels", "decode_route", estimator=estimator,
                   method=method)


def record_cg_iters(iters) -> None:
    """Histogram sample of the fused resolvent solve's CG iteration count
    (dropped when ``iters`` is a jit tracer)."""
    if not registry.enabled():
        return
    registry.observe("kernels", "cg_iters", iters)


def record_compile(component: str, name: str, compile_s: float,
                   steady_s: float) -> None:
    """Gauge pair from ``benchmarks.common.timed_with_compile``: first-call
    (trace + lower + compile) vs steady-state seconds for a jitted fn."""
    if not registry.enabled():
        return
    registry.gauge(component, f"{name}.compile_us", compile_s * 1e6)
    registry.gauge(component, f"{name}.steady_us", steady_s * 1e6)
