"""The metrics registry: counters / gauges / histograms + recording spans.

One process-level registry, DISABLED by default. The overhead contract
(docs/OBSERVABILITY.md) is:

- **disabled (the default)**: every recording call is a single attribute
  check and an immediate return; ``span(...)`` hands back one shared no-op
  context manager. No allocation, no locking, no trace events — the
  instrumented code paths execute the exact same math, so an uninstrumented
  run is bitwise-identical to pre-instrumentation ``main``
  (tests/test_obs.py pins this on all three fl backends).
- **enabled**: recording costs a dict update; spans additionally cost two
  ``perf_counter`` reads and (when a tracer is installed —
  ``repro.obs.trace``) one appended trace event.

Keys are ``component/name`` strings (e.g. ``fl/client_encode.duration_us``,
``kernels/dispatch``), optionally suffixed with sorted ``{k=v,...}`` labels
— the flat namespace every exporter (``--metrics-json``, bench artifacts)
shares.

**Pytree/tracer safety.** Instrumented sites live inside code that other
callers jit (codec encode/decode, the collectives, the CG solve), where
values are ``jax.core.Tracer``s at trace time. The registry never stores
one: ``_scalar_or_none`` rejects tracers (and anything else that will not
``float()``), the recording call silently drops the sample, and the
``obs/tracer_drops`` counter says how many samples were lost to jit. A
traced value therefore never leaks into host state, never triggers a
``TracerLeakError``, and never forces a concretization — recording under
``jax.jit`` is always safe, it just records nothing dynamic. Static values
(Python ints, shapes, dispatch decisions) record fine under jit: they are
trace-time constants, counted once per trace.

Counters are deterministic: same seed + same config => same counter
snapshot (asserted by tests/test_obs.py); durations live in histograms,
which are excluded from that contract.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any

import jax


class _State:
    """The process-level registry state (mutable, host-side only)."""

    __slots__ = ("enabled", "counters", "gauges", "histograms", "tracer_drops")

    def __init__(self):
        self.enabled = False
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, list[float]] = {}
        self.tracer_drops = 0


_STATE = _State()


def enable() -> None:
    """Turn recording on (process-wide)."""
    _STATE.enabled = True


def disable() -> None:
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


def reset() -> None:
    """Drop all recorded metrics (keeps the enabled flag)."""
    _STATE.counters = {}
    _STATE.gauges = {}
    _STATE.histograms = {}
    _STATE.tracer_drops = 0


def _scalar_or_none(v: Any) -> float | None:
    """Host float of ``v``, or None when it cannot be read without forcing a
    traced value (the tracer-safety contract of the module docstring)."""
    if isinstance(v, jax.core.Tracer):
        _STATE.tracer_drops += 1
        return None
    try:
        return float(v)
    except (TypeError, ValueError):
        return None


def _key(component: str, name: str, labels: dict) -> str:
    base = f"{component}/{name}"
    if not labels:
        return base
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{base}{{{inner}}}"


def count(component: str, name: str, value: float = 1, **labels) -> None:
    """Add ``value`` to a counter (keyed by component/name + sorted labels)."""
    if not _STATE.enabled:
        return
    v = _scalar_or_none(value)
    if v is None:
        return
    k = _key(component, name, labels)
    _STATE.counters[k] = _STATE.counters.get(k, 0) + v


def gauge(component: str, name: str, value: float, **labels) -> None:
    """Set a gauge to the latest observed value."""
    if not _STATE.enabled:
        return
    v = _scalar_or_none(value)
    if v is None:
        return
    _STATE.gauges[_key(component, name, labels)] = v


def observe(component: str, name: str, value: float, **labels) -> None:
    """Append a sample to a histogram."""
    if not _STATE.enabled:
        return
    v = _scalar_or_none(value)
    if v is None:
        return
    _STATE.histograms.setdefault(_key(component, name, labels), []).append(v)


class _NullSpan:
    """The shared disabled-mode span: a no-op context manager that still
    yields a dict so call sites may annotate unconditionally."""

    __slots__ = ()

    def __enter__(self):
        return {}

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


def _clean_args(args: dict) -> dict:
    """Trace-event args: strings/bools pass through, numerics become host
    floats, tracers (and anything unreadable) are dropped."""
    out = {}
    for k, v in args.items():
        if isinstance(v, (str, bool)):
            out[k] = v
            continue
        s = _scalar_or_none(v)
        if s is not None:
            out[k] = s
    return out


@contextlib.contextmanager
def _live_span(component: str, name: str, track: str | None, args: dict):
    from . import trace as trace_lib

    clean = _clean_args(args)
    t0 = time.perf_counter()
    ts = trace_lib.now_us()
    try:
        yield clean
    finally:
        dur_us = (time.perf_counter() - t0) * 1e6
        count(component, f"{name}.calls")
        observe(component, f"{name}.duration_us", dur_us)
        tracer = trace_lib.current_tracer()
        if tracer is not None:
            tracer.emit(track or name, f"{component}/{name}", ts, dur_us,
                        _clean_args(clean))


def span(component: str, name: str, *, track: str | None = None, **args):
    """Recording span: times the enclosed block (wall clock of the enclosed
    PYTHON execution — under jit that is trace time; see
    docs/OBSERVABILITY.md), bumps ``<name>.calls``, records a
    ``<name>.duration_us`` histogram sample, and emits one trace event on
    ``track`` when a tracer is installed. Yields a mutable dict: entries
    added inside the block become trace-event args (late annotations)."""
    if not _STATE.enabled:
        return _NULL_SPAN
    return _live_span(component, name, track, args)


def marker(component: str, name: str, *, track: str | None = None, **args) -> None:
    """Zero-duration span: an attribution point on a trace track (e.g. the
    quantize stage, whose walltime is fused into the client encode under
    vmap) plus the same counter bump a span makes."""
    if not _STATE.enabled:
        return
    from . import trace as trace_lib

    count(component, f"{name}.calls")
    tracer = trace_lib.current_tracer()
    if tracer is not None:
        tracer.emit(track or name, f"{component}/{name}", trace_lib.now_us(),
                    0.0, _clean_args(args))


def _summary(samples: list[float]) -> dict:
    n = len(samples)
    s = sorted(samples)
    return {
        "count": n,
        "sum": sum(s),
        "min": s[0],
        "max": s[-1],
        "mean": sum(s) / n,
        "p50": s[n // 2],
    }


def snapshot() -> dict:
    """Serializable view of everything recorded so far. ``counters`` and
    ``gauges`` are flat name->value maps; ``histograms`` are per-key summary
    stats; ``tracer_drops`` counts samples rejected for being jit tracers."""
    return {
        "enabled": _STATE.enabled,
        "counters": dict(sorted(_STATE.counters.items())),
        "gauges": dict(sorted(_STATE.gauges.items())),
        "histograms": {
            k: _summary(v) for k, v in sorted(_STATE.histograms.items())
        },
        "tracer_drops": _STATE.tracer_drops,
    }


def tracer_drops() -> int:
    return _STATE.tracer_drops
