"""Round-timeline tracer: Chrome-trace / Perfetto JSON emission.

A ``Tracer`` collects *complete* duration events (``ph: "X"``) on named
tracks — one track per round phase — and serializes them in the Chrome
Trace Event Format, which Perfetto (https://ui.perfetto.dev) and
``chrome://tracing`` load directly. ``repro.fl.run --trace out.json`` is the
CLI entry; ``tools/trace_report.py`` is the CI validator.

Track layout (docs/OBSERVABILITY.md has the full table):

    round            one span per federated round (args: round, mse,
                     wire_bytes, survivors — ``wire_bytes`` is a summary,
                     deliberately NOT the ledgered ``bytes`` key)
    client_encode    survivor encode per budget group (args carry the exact
                     per-group wire bytes off the payload ledger)
    quantize         attribution marker: the quantizer stage runs fused
                     inside the encode vmap, so it gets a zero-duration
                     marker naming the stage, not a separate walltime
    payload_route    payload traffic (all_gather / all_to_all); args carry
                     the modelled intra-pod bytes — deliberately under a
                     ``bytes_intra_pod`` key so they never pollute the wire
                     ledger sum
    owner_decode     server decode per budget group (monolithic or sharded)
    stale_admission  async staleness-1 admission (args: late-arrival bytes)
    temporal_update  server temporal-state commit + correlation tracker

The byte-ledger invariant the CI trace report asserts: summing the
``bytes`` arg over ALL events equals ``History.total_bytes`` exactly —
``bytes`` rides only on client_encode and stale_admission events, the two
places payloads cross the wire.

Events are emitted through ``repro.obs.span``/``marker`` against the
*installed* tracer (``install_tracer``), so instrumented library code never
threads a tracer argument; with no tracer installed (the default) emission
is skipped at the registry's enabled-check, at zero cost.

A tracer also carries a ``round`` cursor (``set_round``): every event
emitted while round t is current is tagged ``args["round"] = t``, which is
what lets the trace report assert one-span-per-phase-PER-ROUND without the
emitting code knowing the round number.
"""
from __future__ import annotations

import json
import time

# canonical per-round phase tracks, in display order
PHASES = (
    "round",
    "client_encode",
    "quantize",
    "payload_route",
    "owner_decode",
    "stale_admission",
    "temporal_update",
)

_ORIGIN = time.perf_counter()


def now_us() -> float:
    """Microseconds since process trace origin (monotonic)."""
    return (time.perf_counter() - _ORIGIN) * 1e6


class Tracer:
    """Collects Chrome-trace events; one instance per traced run."""

    def __init__(self):
        self.events: list[dict] = []
        self.meta: dict = {}
        self._tids: dict[str, int] = {}
        self._round: int | None = None

    # -------------------------------------------------------------- tracks

    def _tid(self, track: str) -> int:
        if track not in self._tids:
            tid = (PHASES.index(track) if track in PHASES
                   else len(PHASES) + len(self._tids))
            self._tids[track] = tid
            self.events.append({
                "ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                "args": {"name": track},
            })
            self.events.append({
                "ph": "M", "name": "thread_sort_index", "pid": 1, "tid": tid,
                "args": {"sort_index": tid},
            })
        return self._tids[track]

    # ------------------------------------------------------------ emission

    def set_round(self, t: int | None) -> None:
        """Tag subsequent events with ``args["round"] = t``."""
        self._round = t

    def emit(self, track: str, name: str, ts_us: float, dur_us: float,
             args: dict | None = None) -> None:
        """One complete event (``ph: "X"``) on ``track``."""
        a = dict(args or {})
        if self._round is not None and "round" not in a:
            a["round"] = self._round
        self.events.append({
            "ph": "X", "name": name, "pid": 1, "tid": self._tid(track),
            "ts": ts_us, "dur": dur_us, "args": a,
        })

    def counter(self, name: str, ts_us: float, values: dict) -> None:
        """A Chrome counter event (``ph: "C"``) — rendered as a track graph
        (e.g. per-round MSE) by Perfetto."""
        self.events.append({
            "ph": "C", "name": name, "pid": 1, "tid": 0, "ts": ts_us,
            "args": dict(values),
        })

    def set_meta(self, key: str, value) -> None:
        """Run-level metadata (config, ledger totals) carried in the trace
        file's ``metadata`` object — what tools/trace_report.py validates
        the events against."""
        self.meta[key] = value

    # --------------------------------------------------------------- output

    def to_dict(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "metadata": dict(self.meta),
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)


_CURRENT: Tracer | None = None


def install_tracer(tracer: Tracer) -> Tracer:
    """Make ``tracer`` the process-wide emission target (spans/markers from
    any instrumented layer land in it). Returns the tracer."""
    global _CURRENT
    _CURRENT = tracer
    return tracer


def uninstall_tracer() -> None:
    global _CURRENT
    _CURRENT = None


def current_tracer() -> Tracer | None:
    return _CURRENT
