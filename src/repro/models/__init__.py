from .common import LayerSpec, ModelConfig  # noqa: F401
from .transformer import (  # noqa: F401
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_axes,
    param_defs,
)
