"""Activation sharding constraint hook.

§Perf finding (docs/EXPERIMENTS.md, H-c iteration 2): with constraints only on the
batch INPUTS, GSPMD propagated a batch-replicated / d_model-sharded layout
from the embedding gather through every layer — global-batch-sized f32
all-reduces per block (2x2.1GB/device) and redundant logits compute. The
production fix (as in MaxText et al.) is to re-assert the canonical
activation layout (batch over DP axes) at block boundaries.

The model code is mesh-agnostic; launchers install the constraint:

    act_sharding.set_constraint(mesh, P(("pod", "data"), None, None))
"""
from __future__ import annotations

import contextlib

import jax

_SHARDING = None  # NamedSharding for (B, S, D) activations


def set_constraint(sharding) -> None:
    global _SHARDING
    _SHARDING = sharding


@contextlib.contextmanager
def constraint(sharding):
    global _SHARDING
    prev = _SHARDING
    _SHARDING = sharding
    try:
        yield
    finally:
        _SHARDING = prev


def constrain(x):
    """Apply the installed (B, S, D) constraint if any."""
    if _SHARDING is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _SHARDING)
