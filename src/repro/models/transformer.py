"""Config -> model: parameter definitions, init, forward, loss, decode.

One structural source of truth: ``param_defs(cfg)`` returns a pytree of
ParamDef (shape, logical axes, init recipe). init_params / abstract_params /
param_axes are all tree_maps over it, so sharding rules can never drift from
the real parameter tree.

The repeated block pattern is scanned with weights stacked on a leading
"layers" axis (bounded HLO for 95-layer models); prologue/epilogue layers
are unrolled. ``remat="block"`` wraps the scanned block in jax.checkpoint.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import act_sharding, attention, layers, mamba, moe
from .common import LayerSpec, ModelConfig


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple       # logical axis names, len == len(shape)
    init: str = "normal"   # normal | zeros | ones | scaled | a_log | dt_bias
    scale: float = 0.02


def _is_def(x):
    return isinstance(x, ParamDef)


# --------------------------------------------------------------- definitions


def _attn_defs(cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    out = {
        "norm": ParamDef((d,), (None,), "ones"),
        "wq": ParamDef((d, h * dh), ("embed", "heads")),
        "wk": ParamDef((d, kv * dh), ("embed", "heads")),
        "wv": ParamDef((d, kv * dh), ("embed", "heads")),
        "wo": ParamDef((h * dh, d), ("heads", "embed"), "scaled"),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((h * dh,), ("heads",), "zeros")
        out["bk"] = ParamDef((kv * dh,), ("heads",), "zeros")
        out["bv"] = ParamDef((kv * dh,), ("heads",), "zeros")
    return out


def _dense_ffn_defs(cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    out = {
        "norm": ParamDef((d,), (None,), "ones"),
        "w_up": ParamDef((d, d_ff), ("embed", "ff")),
        "w_down": ParamDef((d_ff, d), ("ff", "embed"), "scaled"),
    }
    if cfg.act == "silu":
        out["w_gate"] = ParamDef((d, d_ff), ("embed", "ff"))
    return out


def _moe_defs(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    out = {
        "norm": ParamDef((d,), (None,), "ones"),
        "router": ParamDef((d, e), ("embed", None)),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamDef((e, f, d), ("experts", "ff", "embed"), "scaled"),
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.n_shared_experts * f
        shared = {
            "w_up": ParamDef((d, fs), ("embed", "ff")),
            "w_down": ParamDef((fs, d), ("ff", "embed"), "scaled"),
        }
        if cfg.act == "silu":
            shared["w_gate"] = ParamDef((d, fs), ("embed", "ff"))
        out["shared"] = shared
    return out


def _mamba_defs(cfg: ModelConfig):
    d, di = cfg.d_model, cfg.mamba_d_inner
    nh, conv_dim = cfg.mamba_heads, cfg.mamba_conv_dim
    p_in = 2 * di + 2 * cfg.mamba_ngroups * cfg.d_state + nh
    if cfg.mamba_split_proj:
        proj = {
            "in_z": ParamDef((d, di), ("embed", "mamba_inner")),
            "in_x": ParamDef((d, di), ("embed", "mamba_inner")),
            "in_bc": ParamDef((d, 2 * cfg.mamba_ngroups * cfg.d_state), ("embed", None)),
            "in_dt": ParamDef((d, nh), ("embed", None)),
        }
    else:
        proj = {"in_proj": ParamDef((d, p_in), ("embed", "mamba_inner"))}
    return {
        "norm": ParamDef((d,), (None,), "ones"),
        **proj,
        "conv_w": ParamDef((conv_dim, cfg.d_conv), ("mamba_inner", None)),
        "conv_b": ParamDef((conv_dim,), ("mamba_inner",), "zeros"),
        "dt_bias": ParamDef((nh,), (None,), "dt_bias"),
        "a_log": ParamDef((nh,), (None,), "a_log"),
        "d_skip": ParamDef((nh,), (None,), "ones"),
        "out_norm": ParamDef((di,), ("mamba_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("mamba_inner", "embed"), "scaled"),
    }


def _layer_defs(cfg: ModelConfig, spec: LayerSpec):
    out = {}
    if spec.kind == "attn":
        out["attn"] = _attn_defs(cfg)
    else:
        out["mamba"] = _mamba_defs(cfg)
    if spec.ffn == "dense":
        out["ffn"] = _dense_ffn_defs(cfg, cfg.d_ff)
    elif spec.ffn == "moe":
        out["ffn"] = _moe_defs(cfg)
    return out


def _stack_def(defn: ParamDef, n: int) -> ParamDef:
    return ParamDef((n,) + defn.shape, ("layers",) + defn.axes, defn.init, defn.scale)


def param_defs(cfg: ModelConfig):
    vp, d = cfg.vocab_padded, cfg.d_model
    tree = {
        "embed": ParamDef((vp, d), ("vocab", "embed")),
        "final_norm": ParamDef((d,), (None,), "ones"),
        "prologue": tuple(_layer_defs(cfg, s) for s in cfg.prologue),
        "epilogue": tuple(_layer_defs(cfg, s) for s in cfg.epilogue),
    }
    if cfg.n_blocks > 0:
        block = {
            f"l{i:02d}": _layer_defs(cfg, s) for i, s in enumerate(cfg.block_pattern)
        }
        tree["blocks"] = jax.tree.map(
            lambda pd: _stack_def(pd, cfg.n_blocks), block, is_leaf=_is_def
        )
    else:
        tree["blocks"] = {}
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDef((d, vp), ("embed", "vocab"))
    return tree


# --------------------------------------------------------------------- init


def _init_leaf(defn: ParamDef, key, dtype):
    if defn.init == "zeros":
        return jnp.zeros(defn.shape, dtype)
    if defn.init == "ones":
        return jnp.ones(defn.shape, dtype)
    if defn.init == "a_log":
        base = jnp.log(jnp.linspace(1.0, 16.0, defn.shape[-1]))
        return jnp.broadcast_to(base, defn.shape).astype(dtype)
    if defn.init == "dt_bias":
        dt = jnp.exp(jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), defn.shape[-1]))
        inv = jnp.log(jnp.expm1(dt))
        return jnp.broadcast_to(inv, defn.shape).astype(dtype)
    scale = defn.scale
    if defn.init == "scaled":
        scale = defn.scale / max(1.0, (2.0 * 24.0) ** 0.5)  # residual-branch damping
    return (jax.random.normal(key, defn.shape) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key):
    defs = param_defs(cfg)
    flat, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(flat))
    leaves = [_init_leaf(d, k, cfg.params_dtype) for d, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(cfg: ModelConfig):
    defs = param_defs(cfg)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, cfg.params_dtype), defs, is_leaf=_is_def
    )


def param_axes(cfg: ModelConfig):
    defs = param_defs(cfg)
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


# ------------------------------------------------------------------ forward


def _run_layer(cfg, spec, p, x, aux, positions, cache):
    if spec.kind == "attn":
        x, nc = attention.attn_layer(
            x, p["attn"], cfg, spec, positions=positions,
            cache=None if cache is None else cache["mix"],
        )
    else:
        x, nc = mamba.mamba_layer(
            x, p["mamba"], cfg, cache=None if cache is None else cache["mix"]
        )
    if spec.ffn != "none":
        x, a = moe.ffn_layer(x, p["ffn"], cfg, spec)
        aux = aux + a
    return x, aux, (None if cache is None else {"mix": nc})


def _run_block(cfg, params_block, x, aux, positions, cache_block):
    new_cache = {}
    x = act_sharding.constrain(x)
    for i, spec in enumerate(cfg.block_pattern):
        kkey = f"l{i:02d}"
        c = None if cache_block is None else cache_block[kkey]
        x, aux, nc = _run_layer(cfg, spec, params_block[kkey], x, aux, positions, c)
        if cache_block is not None:
            new_cache[kkey] = nc
    return x, aux, (new_cache if cache_block is not None else None)


def backbone(params, cfg: ModelConfig, x, positions, cache=None):
    """Run all layers. x: (B, S, D). Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_pro = []
    for i, spec in enumerate(cfg.prologue):
        c = None if cache is None else cache["prologue"][i]
        x, aux, nc = _run_layer(cfg, spec, params["prologue"][i], x, aux, positions, c)
        new_pro.append(nc)

    if cfg.n_blocks > 0:
        if cache is None and cfg.force_unroll:
            # cost-calibration path: no while loops in the compiled HLO
            def one_block(xx, aa, p_block):
                xx, aa, _ = _run_block(cfg, p_block, xx, aa, positions, None)
                return xx, aa

            if cfg.remat == "block":
                one_block = jax.checkpoint(one_block)
            for i in range(cfg.n_blocks):
                p_block = jax.tree.map(lambda l: l[i], params["blocks"])
                x, aux = one_block(x, aux, p_block)
            new_blocks = None
        elif cache is None:

            def body(carry, p_block):
                xx, aa = carry
                xx, aa, _ = _run_block(cfg, p_block, xx, aa, positions, None)
                return (xx, aa), None

            if cfg.remat == "block":
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
            new_blocks = None
        elif cfg.force_unroll:
            ncs = []
            for i in range(cfg.n_blocks):
                p_block = jax.tree.map(lambda l: l[i], params["blocks"])
                c_block = jax.tree.map(lambda l: l[i], cache["blocks"])
                x, aux, nc = _run_block(cfg, p_block, x, aux, positions, c_block)
                ncs.append(nc)
            new_blocks = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)
        else:

            def body(carry, xs):
                xx, aa = carry
                p_block, c_block = xs
                xx, aa, nc = _run_block(cfg, p_block, xx, aa, positions, c_block)
                return (xx, aa), nc

            (x, aux), new_blocks = jax.lax.scan(
                body, (x, aux), (params["blocks"], cache["blocks"])
            )

    new_epi = []
    for i, spec in enumerate(cfg.epilogue):
        c = None if cache is None else cache["epilogue"][i]
        x, aux, nc = _run_layer(cfg, spec, params["epilogue"][i], x, aux, positions, c)
        new_epi.append(nc)

    new_cache = None
    if cache is not None:
        new_cache = {
            "prologue": tuple(new_pro),
            "blocks": new_blocks if cfg.n_blocks > 0 else {},
            "epilogue": tuple(new_epi),
        }
    return x, aux, new_cache


def embed_inputs(params, cfg: ModelConfig, inputs):
    if cfg.input_mode == "tokens":
        x = layers.embed_tokens(inputs, params["embed"], cfg.compute_dtype)
    else:
        x = inputs.astype(cfg.compute_dtype)
    return act_sharding.constrain(x)


def logits_fn(params, cfg: ModelConfig, x):
    x = act_sharding.constrain(x)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def forward(params, cfg: ModelConfig, inputs, positions=None):
    """Train/prefill forward. inputs: (B, S) tokens or (B, S, D) embeds."""
    b, s = inputs.shape[0], inputs.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_inputs(params, cfg, inputs)
    x, aux, _ = backbone(params, cfg, x, positions)
    return logits_fn(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token CE (+ router aux). batch: {"inputs", "labels"}; labels<0 ignored."""
    logits, aux = forward(params, cfg, batch["inputs"])
    labels = batch["labels"]
    valid = labels >= 0
    labels_safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------- decode


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype

    def one(spec: LayerSpec):
        if spec.kind == "attn":
            return {"mix": attention.init_attn_cache(cfg, spec, batch, seq_len, dtype)}
        return {"mix": mamba.init_mamba_cache(cfg, batch, dtype)}

    cache = {
        "prologue": tuple(one(s) for s in cfg.prologue),
        "epilogue": tuple(one(s) for s in cfg.epilogue),
    }
    if cfg.n_blocks > 0:
        block = {f"l{i:02d}": one(s) for i, s in enumerate(cfg.block_pattern)}
        cache["blocks"] = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (cfg.n_blocks,) + l.shape).astype(l.dtype),
            block,
        )
    else:
        cache["blocks"] = {}
    return cache


def decode_step(params, cfg: ModelConfig, cache, inputs, positions):
    """One-token decode. inputs: (B, 1) tokens or (B, 1, D); positions: (B, 1).

    Returns (logits (B, 1, vocab_padded) f32, new_cache).
    """
    x = embed_inputs(params, cfg, inputs)
    x, _, new_cache = backbone(params, cfg, x, positions, cache)
    return logits_fn(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, cache, inputs):
    """Prefill the cache from a full prompt; logits for the LAST position only
    (avoids materialising (B, S, vocab) at 32k prompt lengths)."""
    b, s = inputs.shape[0], inputs.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_inputs(params, cfg, inputs)
    x, _, new_cache = backbone(params, cfg, x, positions, cache)
    return logits_fn(params, cfg, x[:, -1:]), new_cache
