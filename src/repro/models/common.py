"""Model configuration: a layer-pattern spec covering every assigned arch.

A model is  prologue + block_pattern * n_blocks + epilogue  of LayerSpecs.
The repeated block is scanned (weights stacked on a leading "layers" axis)
to keep HLO size and compile time bounded for 60-95 layer models; irregular
leading/trailing layers are unrolled. Interleaved patterns (gemma3 5:1
local:global, jamba 1-attn:7-mamba with alternating MoE) are expressed as a
multi-layer block pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: Literal["attn", "mamba"] = "attn"
    window: int = 0                      # 0 = global attention, else SWA size
    ffn: Literal["dense", "moe", "none"] = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab_size: int
    # layer layout
    block_pattern: tuple[LayerSpec, ...]
    n_blocks: int
    prologue: tuple[LayerSpec, ...] = ()
    epilogue: tuple[LayerSpec, ...] = ()
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # dense ffn
    d_ff: int = 0
    act: Literal["silu", "gelu"] = "silu"   # silu => gated (SwiGLU); gelu => plain
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # mamba (SSD)
    d_state: int = 0
    d_conv: int = 4
    mamba_d_inner: int = 0
    mamba_headdim: int = 64
    mamba_ngroups: int = 1
    mamba_chunk: int = 256
    # perf knob (docs/EXPERIMENTS.md §Perf H-a): split the fused in_proj into
    # separate z/x/BC/dt projections so the big z/x output dims are
    # TP-divisible (the fused width 2*di+2gN+nh generally is not) — pure
    # layout change, functionally identical.
    mamba_split_proj: bool = False
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    input_mode: Literal["tokens", "embeddings"] = "tokens"
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    remat: Literal["none", "block"] = "block"
    # Cost-calibration mode (launch/dryrun.py --calibrate): python-loop over
    # blocks + fully-unrolled inner scans, so the compiled HLO has NO while
    # loops and cost_analysis()/collective parsing are exact. Used at reduced
    # n_blocks (1, 2) and affine-extrapolated to full depth.
    force_unroll: bool = False
    attn_kv_block: int = 1024            # flash-style kv chunk for train/prefill
    attn_impl: Literal["blocked", "flash"] = "blocked"  # flash = Pallas kernel
    # perf knob (docs/EXPERIMENTS.md §Perf): materialise GQA as MHA activations
    # (repeat kv heads to n_heads right after projection). Bit-identical
    # outputs; makes the kv activation head-dim TP-divisible when
    # n_kv_heads < model-axis size (kv=8 on a 16-way axis otherwise forces
    # GSPMD rematerialisation all-gathers every layer).
    gqa_repeat_kv: bool = False
    vocab_pad_multiple: int = 256
    # which shapes this arch supports (docs/DESIGN.md §6)
    supports_long_context: bool = False  # sub-quadratic (SSM/hybrid/SWA)

    # ------------------------------------------------------------- derived
    @property
    def layers(self) -> tuple[LayerSpec, ...]:
        return self.prologue + self.block_pattern * self.n_blocks + self.epilogue

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def mamba_heads(self) -> int:
        return self.mamba_d_inner // self.mamba_headdim if self.mamba_d_inner else 0

    @property
    def mamba_conv_dim(self) -> int:
        # conv runs over (x, B, C) as in Mamba2
        return self.mamba_d_inner + 2 * self.mamba_ngroups * self.d_state

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Total parameter count (matches init_params; used for 6ND roofline)."""
        from . import transformer  # lazy: avoid import cycle

        import jax

        defs = transformer.param_defs(self)
        leaves = jax.tree_util.tree_leaves(defs, is_leaf=lambda x: isinstance(x, transformer.ParamDef))
        total = 0
        for leaf in leaves:
            sz = 1
            for s in leaf.shape:
                sz *= s
            total += sz
        return total

    def n_params_active(self) -> int:
        """Active (per-token) parameters: MoE counts shared + top_k routed."""
        if self.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        # subtract the non-activated routed experts' weights
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = sum(1 for l in self.layers if l.ffn == "moe")
        inactive = n_moe_layers * (self.n_experts - self.top_k_experts) * per_expert
        return total - inactive
