"""GQA attention: kv-block-scanned (flash-style) for train/prefill, dense for
single-token decode. Supports causal masking, sliding windows, QKV bias and
ring-buffer KV caches with explicit stored positions.

Memory note (docs/DESIGN.md / EXPERIMENTS §Perf): the kv-block online-softmax scan
bounds the live score tensor to (B, Sq, H, kv_block) instead of
(B, Sq, H, Sk) — the difference between 8.6 GB and 0.27 GB per device at
prefill_32k scale.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import layers

_NEG = -1e9


def _mask(q_pos, k_pos, window: int):
    """(B, Sq, Sk) bool. k_pos = -1 marks invalid (unfilled cache) slots."""
    q = q_pos[:, :, None]
    k = k_pos[:, None, :]
    m = (k <= q) & (k >= 0)
    if window > 0:
        m &= q - k < window
    return m


def attention_dense(q, k, v, q_pos, k_pos, window: int):
    """One-shot attention (used for decode / short sequences).

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh); *_pos: (B, S*) int32.
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qr = q.reshape(b, sq, kvh, rep, dh)
    s = jnp.einsum("bqgrd,bkgd->bqgrk", qr, k, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    m = _mask(q_pos, k_pos, window)[:, :, None, None, :]
    s = jnp.where(m, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqgrk,bkgd->bqgrd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dh)


def attention_blocked(q, k, v, q_pos, k_pos, window: int, kv_block: int, unroll=1):
    """Online-softmax scan over kv blocks (pure-JAX flash attention)."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qr = (q.reshape(b, sq, kvh, rep, dh)).astype(jnp.float32)
    kb = min(kv_block, sk)
    nb = -(-sk // kb)
    pad = nb * kb - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kblocks = k.reshape(b, nb, kb, kvh, dh).transpose(1, 0, 2, 3, 4)
    vblocks = v.reshape(b, nb, kb, kvh, dh).transpose(1, 0, 2, 3, 4)
    pblocks = k_pos.reshape(b, nb, kb).transpose(1, 0, 2)
    scale = 1.0 / math.sqrt(dh)

    def step(carry, blk):
        m, l, acc = carry
        kb_, vb_, kp = blk
        s = jnp.einsum("bqgrd,bkgd->bqgrk", qr, kb_.astype(jnp.float32)) * scale
        msk = _mask(q_pos, kp, window)[:, :, None, None, :]
        s = jnp.where(msk, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqgrk,bkgd->bqgrd", p, vb_.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    init = (
        jnp.full((b, sq, kvh, rep), _NEG, jnp.float32),
        jnp.zeros((b, sq, kvh, rep), jnp.float32),
        jnp.zeros((b, sq, kvh, rep, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (kblocks, vblocks, pblocks), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attn_layer(x, p, cfg, spec, *, positions, cache=None, layer_slot=None):
    """Full attention layer (pre-norm, residual). Returns (y, new_cache_slot).

    Train/prefill: cache is None, attends causally within x.
    Decode:        cache = {"k","v","pos"}; x is (B, 1, D); new kv written at
                   slot positions % S_alloc (ring buffer when windowed).
    """
    b, s, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    q = layers.dense(xn, p["wq"], p.get("bq")).reshape(b, s, h, dh)
    k = layers.dense(xn, p["wk"], p.get("bk")).reshape(b, s, kvh, dh)
    v = layers.dense(xn, p["wv"], p.get("bv")).reshape(b, s, kvh, dh)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    if cfg.gqa_repeat_kv and kvh < h and cache is None:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
        kvh = h

    if cache is None or s > 1:
        # train / prefill: attend over the full in-flight k, v (correct across
        # ring-buffer eviction), flash-scanned when long.
        if cfg.attn_impl == "flash":
            # Pallas VMEM-tiled kernel (positions assumed contiguous per row)
            from ..kernels import ops as kops

            qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
            kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, dh)
            vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dh)
            of = kops.flash_attention(qf, kf, vf, rep=h // kvh, window=spec.window)
            o = of.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
        elif s > cfg.attn_kv_block:
            o = attention_blocked(q, k, v, positions, positions, spec.window,
                                  cfg.attn_kv_block, unroll=True if cfg.force_unroll else 1)
        else:
            o = attention_dense(q, k, v, positions, positions, spec.window)
        new_cache = None
        if cache is not None:
            # populate cache with the last s_alloc tokens (scatter at pos % alloc)
            s_alloc = cache["k"].shape[1]
            sa = min(s, s_alloc)
            tail_pos = positions[:, s - sa :]
            idx = tail_pos % s_alloc  # (B, sa)
            rows = jnp.arange(b)[:, None]
            new_cache = {
                "k": cache["k"].at[rows, idx].set(k[:, s - sa :].astype(cache["k"].dtype)),
                "v": cache["v"].at[rows, idx].set(v[:, s - sa :].astype(cache["v"].dtype)),
                "pos": cache["pos"].at[rows, idx].set(tail_pos),
            }
    else:
        s_alloc = cache["k"].shape[1]
        slot = positions[:, 0] % s_alloc  # (B,)
        upd = lambda buf, new: jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(bb, nn, ss, axis=0)
        )(buf, new, slot)
        ck = upd(cache["k"], k.astype(cache["k"].dtype))
        cv = upd(cache["v"], v.astype(cache["v"].dtype))
        cp = jax.vmap(
            lambda bb, nn, ss: jax.lax.dynamic_update_slice_in_dim(bb, nn, ss, axis=0)
        )(cache["pos"], positions[:, :1], slot)
        o = attention_dense(q, ck, cv, positions, cp, spec.window)
        new_cache = {"k": ck, "v": cv, "pos": cp}

    y = layers.dense(o.reshape(b, s, h * dh), p["wo"])
    return x + y, new_cache


def init_attn_cache(cfg, spec, batch: int, seq_len: int, dtype):
    """Empty cache for one attention layer (ring-buffered when windowed)."""
    s_alloc = min(seq_len, spec.window) if spec.window > 0 else seq_len
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, s_alloc, kvh, dh), dtype),
        "v": jnp.zeros((batch, s_alloc, kvh, dh), dtype),
        "pos": jnp.full((batch, s_alloc), -1, jnp.int32),
    }
