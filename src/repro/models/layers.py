"""Primitive layers (pure JAX, no flax): norms, rope, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, dh); positions: broadcastable (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def mlp(x, p, act: str):
    """Gated SwiGLU (act='silu') or plain GeLU MLP (act='gelu')."""
    if act == "silu":
        h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    else:
        h = jax.nn.gelu(dense(x, p["w_up"]))
    return dense(h, p["w_down"])


def embed_tokens(tokens, table, compute_dtype):
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)
