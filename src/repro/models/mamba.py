"""Mamba2 (SSD — state-space duality) block, pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): within a
chunk the recurrence is materialised as a decay-masked attention-like matmul
(MXU-friendly quadratic-in-Q work), across chunks a lax.scan carries the
(heads, headdim, state) recurrent state. Decode is the O(1) recurrence.

Layout: in_proj -> [z (gate), x, B, C, dt]; short causal conv over (x,B,C);
SSD; gated RMSNorm; out_proj. Jamba's Mamba-1 layers are realised with this
SSD block (state=16, heads=d_inner/headdim) — a documented simplification
(docs/DESIGN.md §7): identical interface, shapes and asymptotics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def _split_proj(cfg, zxbcdt):
    di, g, n, nh = cfg.mamba_d_inner, cfg.mamba_ngroups, cfg.d_state, cfg.mamba_heads
    z, x, bb, cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    return z, x, bb, cc, dt  # dt: (..., nh)


def _conv_train(xbc, w, b):
    """Causal depthwise conv along seq. xbc: (B, S, C); w: (C, K)."""
    k = w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # depthwise: feature_group_count = C
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.T[:, None, :].astype(jnp.float32),  # (K, 1, C) -> spec OIW? use dimension_numbers
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1],
    )
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(x, dt, a_log, bb, cc, dd, chunk: int, unroll=1):
    """Chunked SSD scan.

    x:  (B, L, H, P)   inputs per head
    dt: (B, L, H)      positive step sizes (post-softplus)
    a_log: (H,)        log(-A)
    bb, cc: (B, L, H, N)  input/output projections (groups pre-broadcast)
    dd: (H,)           skip
    -> y (B, L, H, P)
    """
    b, l, h, p = x.shape
    n = bb.shape[-1]
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x, dt, bb, cc = (jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2)) for t in (x, dt, bb, cc))

    f32 = jnp.float32
    xr = x.reshape(b, nc, q, h, p).astype(f32)
    dtr = dt.reshape(b, nc, q, h).astype(f32)
    br = bb.reshape(b, nc, q, h, n).astype(f32)
    cr = cc.reshape(b, nc, q, h, n).astype(f32)

    da = -jnp.exp(a_log.astype(f32)) * dtr  # (b, nc, q, h) log-decay per step
    cs = jnp.cumsum(da, axis=2)  # inclusive cumsum
    xdt = xr * dtr[..., None]

    # intra-chunk: y_q += C_q . sum_{k<=q} exp(cs_q - cs_k) dt_k B_k x_k
    # decay: (b, nc, h, q, k); all exponents <= 0 (stable).
    csh = cs.transpose(0, 1, 3, 2)
    decay = jnp.exp(csh[:, :, :, :, None] - csh[:, :, :, None, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, None], decay, 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cr, br) * decay
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xdt)

    # chunk-end states: S_c = sum_k exp(cs_Q - cs_k) B_k (dt_k x_k)^T
    end_decay = jnp.exp(cs[:, :, -1:, :] - cs)  # (b, nc, q, h)
    states = jnp.einsum("bckhn,bckh,bckhp->bchnp", br, end_decay, xdt)
    total = jnp.exp(cs[:, :, -1, :])  # (b, nc, h) chunk total decay

    def inter(h_carry, inp):
        s_c, tot = inp
        out = h_carry  # state at chunk START
        h_new = h_carry * tot[..., None, None] + s_c
        return h_new, out

    h0 = jnp.zeros((b, h, n, p), f32)
    h_final, h_prev = jax.lax.scan(
        inter, h0, (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
        unroll=unroll,
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, n, p)

    y_inter = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", cr, jnp.exp(cs), h_prev)
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :l]
    y = y + x.reshape(b, nc * q, h, p)[:, :l].astype(f32) * dd.astype(f32)[None, None, :, None]
    return y, h_final


def mamba_layer(x, p, cfg, *, cache=None):
    """Mamba2 block with residual. Returns (y, new_cache).

    cache = {"conv": (B, K-1, convdim), "ssm": (B, H, N, P)} for decode.
    """
    b, s, _ = x.shape
    di, nh, hd = cfg.mamba_d_inner, cfg.mamba_heads, cfg.mamba_headdim
    g, n = cfg.mamba_ngroups, cfg.d_state
    xn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    if cfg.mamba_split_proj:
        z = layers.dense(xn, p["in_z"])
        xi = layers.dense(xn, p["in_x"])
        bc = layers.dense(xn, p["in_bc"])
        bb, cc = jnp.split(bc, 2, axis=-1)
        dt = layers.dense(xn, p["in_dt"])
    else:
        zxbcdt = layers.dense(xn, p["in_proj"])
        z, xi, bb, cc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    xbc = jnp.concatenate([xi, bb, cc], axis=-1)  # (B, S, convdim)
    if cache is None or s > 1:
        xbc_raw = xbc
        xbc = _conv_train(xbc, p["conv_w"], p["conv_b"])
        xi, bb, cc = jnp.split(xbc, [di, di + g * n], axis=-1)
        xh = xi.reshape(b, s, nh, hd)
        bh = jnp.repeat(bb.reshape(b, s, g, n), nh // g, axis=2)
        ch = jnp.repeat(cc.reshape(b, s, g, n), nh // g, axis=2)
        y, h_final = ssd_chunked(xh, dt, p["a_log"], bh, ch, p["d_skip"],
                                 cfg.mamba_chunk, unroll=True if cfg.force_unroll else 1)
        new_cache = None
        if cache is not None:
            # prefill: conv history = last (K-1) PRE-activation inputs
            kconv = p["conv_w"].shape[-1]
            hist = jnp.concatenate([cache["conv"], xbc_raw], axis=1)[:, -(kconv - 1):]
            new_cache = {"conv": hist, "ssm": h_final}
    else:
        # ---- O(1) recurrent decode (s == 1) -----------------------------
        kconv = p["conv_w"].shape[-1]
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K, convdim)
        conv_out = jnp.einsum("bkc,ck->bc", hist.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        xbc1 = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        new_conv = hist[:, 1:]
        xi, bb, cc = jnp.split(xbc1, [di, di + g * n], axis=-1)
        xh = xi.reshape(b, nh, hd)
        bh = jnp.repeat(bb.reshape(b, g, n), nh // g, axis=1)
        ch = jnp.repeat(cc.reshape(b, g, n), nh // g, axis=1)
        dt1 = dt[:, 0]  # (B, H)
        da = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt1)  # (B, H)
        upd = jnp.einsum("bhn,bhp->bhnp", bh.astype(jnp.float32), xh.astype(jnp.float32) * dt1[..., None])
        ssm = cache["ssm"] * da[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), ssm)
        y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
        y = y[:, None]  # (B, 1, H, P)
        new_cache = {"conv": new_conv, "ssm": ssm}

    yf = y.reshape(b, s, di)
    yf = layers.rms_norm(yf.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    yf = yf * jax.nn.silu(z)
    out = layers.dense(yf, p["out_proj"])
    return x + out, new_cache


def init_mamba_cache(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.mamba_conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.mamba_heads, cfg.d_state, cfg.mamba_headdim), jnp.float32),
    }
