"""Mixture-of-Experts FFN: top-k routing with sort-based grouped matmul.

TPU adaptation: instead of the GShard one-hot dispatch tensor
(tokens x experts x capacity — O(T*E*C) bytes, prohibitive at 32k tokens),
tokens are argsorted by expert id and packed into a fixed (E, C, D) buffer;
expert FFNs run as E-batched MXU matmuls; outputs scatter back to token
order. Capacity overflow tokens are dropped (standard practice; the residual
connection carries them) — capacity_factor controls the drop rate.

Supports shared experts (DeepSeekMoE) that process every token densely.
Returns a load-balance auxiliary loss (Switch-style) accumulated by the
training loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k_experts / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_ffn(x, p, cfg):
    """x: (T, D) -> (y (T, D), aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k_experts
    cap = capacity(cfg, t)

    logits = (x.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, k)  # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * p_e
    ids_onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)  # (T, K, E)
    frac_tokens = ids_onehot.sum((0, 1)) / (t * k)
    frac_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = sel.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    src_tok = order // k
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos_in_e, cap - 1)].add(
        jnp.where(keep[:, None], x[src_tok], 0.0)
    )

    # ---- E-batched expert FFN (MXU) ------------------------------------
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype)))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))

    # ---- combine back ---------------------------------------------------
    gathered = y_buf[sorted_e, jnp.clip(pos_in_e, 0, cap - 1)]  # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    slot_out = jnp.zeros((t * k, d), x.dtype).at[order].set(gathered)
    y = (slot_out.reshape(t, k, d) * gate[..., None].astype(x.dtype)).sum(1)

    if cfg.n_shared_experts > 0:
        y = y + layers.mlp(x, p["shared"], cfg.act)
    return y, aux


def ffn_layer(x, p, cfg, spec):
    """Pre-norm FFN residual block; dispatches dense vs MoE. -> (y, aux)."""
    if spec.ffn == "none":
        return x, jnp.zeros((), jnp.float32)
    b, s, d = x.shape
    xn = layers.rms_norm(x, p["norm"], cfg.norm_eps)
    if spec.ffn == "dense":
        return x + layers.mlp(xn, p, cfg.act), jnp.zeros((), jnp.float32)
    y, aux = moe_ffn(xn.reshape(b * s, d), p, cfg)
    return x + y.reshape(b, s, d), aux
