"""Picklable worker entry points for ``spawn_local``.

``multiprocessing``'s spawn context re-imports a worker by qualified module
name in the child, so anything spawned from tests or benchmarks must live in
an importable module — ``python -c`` ``__main__`` functions don't unpickle.
These workers are the canned bodies ``tests/test_runtime.py`` and
``benchmarks/bench_multihost.py`` share: build an FL round setup from a
plain-dict spec (plain so it pickles across the spawn boundary), run it
under the process's ``RuntimeContext``, return plain numpy results.
"""
from __future__ import annotations

import time

import numpy as np

_STAGES = None


def _stage_registry():
    global _STAGES
    if _STAGES is None:
        from repro.core import codec

        _STAGES = {
            "identity": codec.Identity,
            "rand_k": codec.RandK,
            "rand_k_spatial": codec.RandKSpatial,
            "rand_proj_spatial": codec.RandProjSpatial,
            "top_k": codec.TopK,
            "int8": codec.Int8Quant,
            "bf16": codec.Bf16Quant,
            "error_feedback": codec.ErrorFeedback,
            "temporal": codec.Temporal,
        }
    return _STAGES


def build_pipeline(stage_specs):
    """[(stage_name, kwargs), ...] -> codec.Pipeline. The picklable
    pipeline description used in worker specs."""
    from repro.core import codec

    reg = _stage_registry()
    return codec.Pipeline([reg[name](**dict(kw)) for name, kw in stage_specs])


def history_arrays(hist) -> dict:
    """History -> plain float64 numpy arrays (NaN-safe, pickle-exact): the
    comparable trajectory a parity test asserts bitwise across process
    counts."""
    keys = ("metric", "mse", "mse_pop", "bytes", "n_survivors", "n_sampled",
            "n_stale", "stale_bytes", "intra_pod_bytes", "dcn_bytes",
            "rho_hat")
    return {k: np.asarray(getattr(hist, k), dtype=np.float64) for k in keys}


def round_worker(ctx, spec: dict) -> dict:
    """Run ``fl.run_rounds`` hierarchically under ``ctx``.

    ``spec`` (all plain): task/task_kw, stages (for ``build_pipeline``),
    cohort kwargs, and RoundConfig kwargs (``rounds`` dict; ``hierarchy``/
    ``pods`` ride there). Every process runs the identical global
    simulation and decodes its owned pods; the returned History is
    identical on all processes by the exchange contract, so the caller may
    compare any/all of them.
    """
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    task = get_task(spec["task"], **dict(spec.get("task_kw", {})))
    pipe = build_pipeline(spec["stages"])
    cohort = Cohort(**dict(spec.get("cohort", {})))
    cfg = RoundConfig(runtime=ctx, **dict(spec.get("rounds", {})))
    t0 = time.perf_counter()
    _, hist = run_rounds(task, pipe, cohort, cfg)
    out = history_arrays(hist)
    out["wall_s"] = time.perf_counter() - t0
    out["process_id"] = ctx.process_id
    out["total_bytes"] = hist.total_bytes
    out["total_dcn_bytes"] = hist.total_dcn_bytes
    out["total_intra_pod_bytes"] = hist.total_intra_pod_bytes
    return out


def kv_roundtrip_worker(ctx, shape=(3, 5)) -> dict:
    """Transport self-test: every process publishes a deterministic array,
    reads every peer's, and asserts bit-exact recovery. Returns the checksum
    map (also exercised single-process, where the exchange short-circuits).
    """
    import pickle

    rng = np.random.default_rng(1234 + ctx.process_id)
    mine = rng.standard_normal(shape).astype(np.float32)
    if ctx.is_distributed:
        ctx.put_bytes(f"kvtest/{ctx.process_id}", pickle.dumps(mine))
        ctx.barrier("kvtest-ready")
    sums = {}
    for p in range(ctx.n_processes):
        if p == ctx.process_id:
            arr = mine
        else:
            arr = pickle.loads(ctx.get_bytes(f"kvtest/{p}"))
            expect = np.random.default_rng(1234 + p).standard_normal(
                shape).astype(np.float32)
            assert arr.tobytes() == expect.tobytes(), f"peer {p} corrupt"
        sums[p] = float(arr.sum())
    if ctx.is_distributed:
        ctx.barrier("kvtest-done")
    return sums
