"""Cross-pod communication: the exact record exchange and the two-tier
byte model (docs/DESIGN.md §11.3).

Two tiers of traffic exist once aggregation goes hierarchical:

- **intra-pod (ICI)** — payload routing inside one pod's server decode.
  Already modelled by ``dist.collectives.intra_pod_traffic`` and ledgered in
  ``History.intra_pod_bytes``; pods reuse it unchanged (each pod's decode is
  a smaller instance of the same problem).
- **cross-pod (DCN)** — what crosses pod boundaries. Flat aggregation ships
  every non-root survivor's PAYLOAD to the root (n·k-ish bytes); the
  hierarchical route ships each contributing pod's d-sized DECODED estimate
  up and the combined mean back down (d-ish bytes) — the accuracy-vs-
  communication trade of Konečný & Richtárik, which wins exactly in the
  n·k > d regime the paper's estimators target. ``cross_pod_traffic``
  models both routes; ``History.dcn_bytes`` ledgers the route taken.

``CrossPodExchange`` is the transport that actually moves the per-pod
records between processes: the ``jax.distributed`` coordinator KV store
(bit-exact byte round-trip; XLA cross-process collectives don't exist on
the CPU backend). On-device meshes combine decoded tiles with
``dist.collectives.psum_scatter_mean`` instead (re-exported here) — same
math, DCN traffic (P-1)/P of the naive all-reduce.

Trace contract: DCN bytes annotate spans under the key ``bytes_dcn`` (like
``bytes_intra_pod``), never ``bytes`` — the Perfetto gate
(tools/trace_report.py) sums ``bytes`` exactly against the wire ledger and
modelled tiers must not enter that sum.
"""
from __future__ import annotations

import pickle

import numpy as np

from ..dist.collectives import psum_scatter_mean  # noqa: F401  (re-export)
from .launch import RuntimeContext

__all__ = ["CrossPodExchange", "cross_pod_traffic", "psum_scatter_mean"]


class CrossPodExchange:
    """All-to-all of per-pod round records across runtime processes.

    Each process publishes ONE pickled blob per round — ``{pod: record}``
    for every pod it owns (possibly empty, so remote gets never hang) — then
    blocking-gets every other process's blob. Records are plain dicts of
    numpy arrays + scalars; numpy round-trips pickle bit-exactly, which is
    what the 2-process == 1-process bitwise-parity contract rides on.

    Single-process contexts (or ``ctx=None``) short-circuit: the owned
    records ARE the global records. A monotone per-instance sequence number
    keys each round's blobs and barriers so rounds can never alias; the
    publisher deletes its blob after the exit barrier.
    """

    def __init__(self, ctx: RuntimeContext | None = None):
        self.ctx = ctx
        self._seq = 0

    def exchange(self, owned: dict) -> dict:
        """``owned``: {pod_id: record} for this process's pods. Returns the
        union over all processes, exactly once per call site per round."""
        ctx = self.ctx
        if ctx is None or not ctx.is_distributed:
            return dict(owned)
        seq = self._seq
        self._seq += 1
        key = f"repro/xpod/{seq}/{ctx.process_id}"
        ctx.put_bytes(key, pickle.dumps(owned, protocol=pickle.HIGHEST_PROTOCOL))
        ctx.barrier(f"repro/xpod-ready/{seq}")
        records = dict(owned)
        for p in range(ctx.n_processes):
            if p != ctx.process_id:
                records.update(pickle.loads(
                    ctx.get_bytes(f"repro/xpod/{seq}/{p}")))
        ctx.barrier(f"repro/xpod-done/{seq}")
        ctx.delete(key)
        return records


def cross_pod_traffic(pipe, cohort, survivors, plan, n_chunks: int, *,
                      stale_pods: int = 0, hierarchy: str = "hier") -> dict:
    """Modelled cross-pod (DCN-tier) bytes of one round's aggregation.

    - ``dcn_bytes_flat``: flat aggregation to a root server placed in pod 0
      — every survivor OUTSIDE pod 0 ships its full payload across the pod
      boundary, per budget group:
      ``sum_g n_nonroot_g * payload_nbytes_g(n_chunks)``.
    - ``dcn_bytes_hier``: the hierarchical route — each contributing
      non-root pod ships ONE d-sized decoded estimate up (float32), pods
      that additionally admitted a stale group ship that stale mean too
      (``stale_pods`` of them), and the combined mean broadcasts back down
      to the other P-1 pods:
      ``(n_contributing_nonroot + stale_pods + n_pods - 1) * C * d_block * 4``.
    - ``dcn_bytes``: the route actually taken — hier at ``n_pods >= 2``,
      else 0 (one pod / flat: nothing crosses a pod boundary; the root IS
      the server). This is the ``History.dcn_bytes`` column.

    The hier route wins exactly when payload bytes exceed estimate bytes —
    the n·k > d regime (asserted in tests/test_runtime.py and reported by
    benchmarks/bench_multihost.py).
    """
    pods = np.asarray([plan.pod_of(int(i)) for i in np.asarray(survivors)],
                      dtype=np.int64)
    est_nbytes = n_chunks * pipe.d_block * 4

    flat = 0
    for k_g, ids_g in cohort.budget_groups(survivors, pipe.k):
        if len(ids_g) == 0:
            continue
        n_nonroot = int(np.sum(
            np.asarray([plan.pod_of(int(i)) for i in ids_g]) != 0))
        flat += n_nonroot * pipe.with_budget(k_g).payload_nbytes(n_chunks)

    contributing_nonroot = int(len({int(p) for p in pods} - {0}))
    up = (contributing_nonroot + int(stale_pods)) * est_nbytes
    down = (plan.n_pods - 1) * est_nbytes
    hier = up + down if plan.n_pods > 1 else 0
    taken = hier if (hierarchy == "hier" and plan.n_pods > 1) else 0
    return {
        "n_pods": plan.n_pods,
        "dcn_bytes_flat": int(flat),
        "dcn_bytes_hier": int(hier),
        "dcn_bytes": int(taken),
    }
