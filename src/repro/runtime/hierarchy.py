"""Hierarchical (per-pod) aggregation: clients -> pods -> one global mean.

The tree shape millions of clients require (docs/DESIGN.md §11.2): a
``PodPlan`` assigns clients to pods; each pod's server runs the SAME
correlation-aware sub-decode the flat path runs (``fl.server`` pipeline
resolution + online rho tracking, ``fl.rounds._decode_round``) — but sees
only its cohort's payloads and carries its OWN online R estimate, the
per-pod correlation bookkeeping Rand-k-Spatial's analysis calls for. The
cross-pod combine is then a d-sized weighted mean of decoded estimates
(``combine_records``), with cross-pod traffic modelled and ledgered by
``runtime.comms``.

Exactness contract: at one pod — or with ``RoundConfig(hierarchy="flat")``
— the hierarchical driver is BITWISE identical to the single-process flat
path. Mechanically: a 1-pod plan restricts nothing (``restrict`` preserves
the survivors array exactly), the single pod's ``ServerState`` receives the
same ``ema_update`` sequence the flat global state would, and
``combine_records`` short-circuits a sole contributing pod (returns its
decode unscaled, no combine arithmetic to reassociate floats through).
Pod ownership composes with PR 5 ``ChunkOwnership`` INSIDE each pod: the
pod's sub-decode forwards ``RoundConfig.ownership`` unchanged, so chunk
shards route intra-pod (ICI tier) while pods exchange estimates (DCN tier).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..fl import server as server_lib
from .comms import CrossPodExchange
from .launch import RuntimeContext


@dataclasses.dataclass(frozen=True)
class PodPlan:
    """Clients -> pods, contiguous ceil blocks (the ``ChunkOwnership``
    idiom): pod p owns clients [p*cpp, min((p+1)*cpp, n_clients))."""

    n_clients: int
    n_pods: int

    def __post_init__(self):
        if self.n_pods < 1:
            raise ValueError(f"n_pods must be >= 1, got {self.n_pods}")
        if self.n_clients < self.n_pods:
            raise ValueError(
                f"need at least one client per pod: {self.n_clients} clients "
                f"< {self.n_pods} pods"
            )

    @property
    def clients_per_pod(self) -> int:
        return -(-self.n_clients // self.n_pods)  # ceil

    def slice_for(self, pod: int) -> tuple[int, int]:
        if not 0 <= pod < self.n_pods:
            raise ValueError(f"pod {pod} out of range [0, {self.n_pods})")
        lo = pod * self.clients_per_pod
        return lo, min(lo + self.clients_per_pod, self.n_clients)

    def pod_of(self, client: int) -> int:
        if not 0 <= client < self.n_clients:
            raise ValueError(
                f"client {client} out of range [0, {self.n_clients})"
            )
        return client // self.clients_per_pod

    def clients_of(self, pod: int) -> np.ndarray:
        lo, hi = self.slice_for(pod)
        return np.arange(lo, hi)

    def restrict(self, ids: np.ndarray, pod: int) -> np.ndarray:
        """``ids`` filtered to pod ``pod``, ORDER PRESERVED — the bitwise
        exactness contract rides on this: a 1-pod restrict must return the
        survivors array exactly as the flat path would see it."""
        ids = np.asarray(ids)
        lo, hi = self.slice_for(pod)
        return ids[(ids >= lo) & (ids < hi)]


class HierarchicalAggregator:
    """Per-pod server states + the cross-pod exchange for one run.

    One instance per ``run_rounds`` call (mirrors the flat path's single
    ``ServerState``). ``pod_states[p]`` is pod p's server: its online rho
    EMA advances only on rounds where pod p's cohort contributed, exactly
    as a real pod-local server's would. The GLOBAL ``ServerState`` (owned
    by the round driver) keeps only ``prev_mean`` — the broadcast temporal
    side information is the COMBINED estimate every client receives, so it
    lives above the pods.

    Multi-process: ``ctx`` names this process; it decodes only
    ``owned_pods`` and learns the other pods' records via ``exchange``.
    Every process therefore holds identical combined results each round —
    there is no root, which is what makes the 2-process and 1-process runs
    bitwise comparable.
    """

    def __init__(self, plan: PodPlan, ctx: RuntimeContext | None = None):
        self.plan = plan
        self.ctx = ctx
        self.pod_states = [server_lib.ServerState()
                           for _ in range(plan.n_pods)]
        self.exchange = CrossPodExchange(ctx)

    @property
    def owned_pods(self) -> range:
        if self.ctx is None:
            return range(self.plan.n_pods)
        return self.ctx.pods_owned(self.plan.n_pods)

    def owns_client(self, client: int) -> bool:
        return self.plan.pod_of(client) in self.owned_pods

    def owned_clients(self) -> np.ndarray:
        """All client ids of this process's pods, ascending (owned pods are
        a contiguous range of contiguous blocks)."""
        pods = self.owned_pods
        if len(pods) == 0:
            return np.arange(0)
        lo, _ = self.plan.slice_for(pods[0])
        _, hi = self.plan.slice_for(pods[-1])
        return np.arange(lo, hi)


def combine_records(records: dict, key: str = "mean", count_key: str = "n"):
    """Cross-pod combine: client-count-weighted mean of per-pod decodes.

    ``records``: {pod: {key: (C, d_block) ndarray | None, count_key: int}}.
    Pods with count 0 (or a None estimate) contribute nothing. Returns
    (combined (C, d_block) | None, n_total, rounded per-pod weights dict).

    Determinism contract: summation runs in ASCENDING pod order on float32
    numpy, so every process — whatever subset of pods it decoded locally —
    reduces the exchanged records identically, bit for bit. A sole
    contributing pod short-circuits: its decode is returned UNSCALED (no
    ``*(n/n)`` round-trip), which is what makes the 1-pod hierarchy
    bitwise identical to the flat path.
    """
    live = [(p, r) for p, r in sorted(records.items())
            if r.get(count_key, 0) > 0 and r.get(key) is not None]
    n_total = int(sum(r[count_key] for _, r in live))
    if not live:
        return None, 0, {}
    if len(live) == 1:
        p, r = live[0]
        return np.asarray(r[key]), n_total, {p: 1.0}
    combined = None
    weights = {}
    for p, r in live:
        w = r[count_key] / n_total
        weights[p] = w
        term = np.asarray(r[key]) * np.float32(w)
        combined = term if combined is None else combined + term
    return combined, n_total, weights


def combine_rho(records: dict) -> float | None:
    """Client-count-weighted mean of the pods' per-round rho measurements
    (the cross-pod view of ``fl.rounds``'s per-group combine). None when no
    pod measured."""
    parts = [(r["rho"], r["n"]) for r in records.values()
             if r.get("rho") is not None and r.get("n", 0) > 0]
    if not parts:
        return None
    if len(parts) == 1:
        # no ``*n/n`` float round-trip: the sole pod's measurement must hit
        # the History bitwise identically to the flat path's
        return float(parts[0][0])
    wsum = sum(n for _, n in parts)
    return float(sum(rho * n for rho, n in parts) / wsum)
