"""Multi-host bootstrap: topology discovery, ``jax.distributed`` init, and a
CPU process spawner so the whole runtime is exercisable in CI without TPUs.

Three pieces (docs/DESIGN.md §11.1):

- ``Topology`` — the process-topology descriptor: how many processes
  (hosts), which one this is, where the coordinator lives, and how many XLA
  host devices each process exposes. ``Topology.from_env()`` reads the
  ``REPRO_*`` variables (falling back to single-process) so the same worker
  code runs under ``spawn_local``, a cluster launcher, or bare.
- ``initialize(topo)`` — calls ``jax.distributed.initialize`` exactly once
  for multi-process topologies and returns a ``RuntimeContext`` wrapping the
  coordinator's key-value store. On the CPU backend cross-process XLA
  collectives are unavailable (the backend refuses multiprocess programs),
  so the KV store + barrier IS the cross-pod transport: numpy arrays round-
  trip bit-exactly through ``put_bytes``/``get_bytes``
  (``runtime.comms.CrossPodExchange`` builds on exactly this). On TPU/GPU
  meshes the same context coexists with real device collectives
  (``dist.collectives.psum_scatter_mean`` is the device-side fast path).
- ``spawn_local(worker, n)`` — forks ``n`` fresh CPU processes (spawn
  context: children re-import, so env set here governs their jax), wires
  them to a coordinator on a free localhost port, runs
  ``worker(ctx, *args)`` in each, and returns the per-process results.

Coordinator discovery order: explicit argument > ``REPRO_COORDINATOR`` >
single-process (no coordinator needed).
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import socket
import traceback

# env keys the spawner sets and Topology.from_env reads
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_LOCAL_DEVICES"

_DEFAULT_TIMEOUT_MS = 120_000


@dataclasses.dataclass(frozen=True)
class Topology:
    """Process topology: hosts x (pods live above, in ``PodPlan``) x local
    devices. One instance per process; ``process_id`` names this process."""

    n_processes: int = 1
    process_id: int = 0
    coordinator: str | None = None  # "host:port"; required when n_processes > 1
    local_devices: int = 1          # XLA host devices this process exposes

    def __post_init__(self):
        if self.n_processes < 1:
            raise ValueError(f"n_processes must be >= 1, got {self.n_processes}")
        if not 0 <= self.process_id < self.n_processes:
            raise ValueError(
                f"process_id {self.process_id} out of range "
                f"[0, {self.n_processes})"
            )
        if self.n_processes > 1 and not self.coordinator:
            raise ValueError("multi-process topology needs a coordinator "
                             "address (host:port)")

    @classmethod
    def from_env(cls, coordinator: str | None = None,
                 n_processes: int | None = None,
                 process_id: int | None = None) -> "Topology":
        """Env-discovered topology; explicit arguments win over env vars."""
        return cls(
            n_processes=int(n_processes if n_processes is not None
                            else os.environ.get(ENV_NUM_PROCESSES, "1")),
            process_id=int(process_id if process_id is not None
                           else os.environ.get(ENV_PROCESS_ID, "0")),
            coordinator=(coordinator if coordinator is not None
                         else os.environ.get(ENV_COORDINATOR) or None),
            local_devices=int(os.environ.get(ENV_LOCAL_DEVICES, "1")),
        )


class RuntimeContext:
    """One process's handle on the multi-host runtime.

    Wraps the topology plus (multi-process only) the ``jax.distributed``
    coordinator's key-value store — the exact-byte transport the CPU
    hierarchical decode exchanges pod records through. Single-process
    contexts have no store; ``barrier``/``put_bytes`` are no-ops/errors so
    callers can treat "1 process" uniformly via ``is_distributed``.
    """

    def __init__(self, topo: Topology, kv_client=None):
        self.topo = topo
        self._kv = kv_client

    # ------------------------------------------------------------ topology

    @property
    def n_processes(self) -> int:
        return self.topo.n_processes

    @property
    def process_id(self) -> int:
        return self.topo.process_id

    @property
    def is_distributed(self) -> bool:
        return self.topo.n_processes > 1

    def pods_owned(self, n_pods: int) -> range:
        """Contiguous ceil-block pod ownership (the ``ChunkOwnership``
        idiom): process i owns pods [i*cpp, min((i+1)*cpp, P))."""
        cpp = -(-n_pods // self.n_processes)  # ceil
        lo = min(self.process_id * cpp, n_pods)
        return range(lo, min(lo + cpp, n_pods))

    def owner_of_pod(self, pod: int, n_pods: int) -> int:
        if not 0 <= pod < n_pods:
            raise ValueError(f"pod {pod} out of range [0, {n_pods})")
        cpp = -(-n_pods // self.n_processes)
        return pod // cpp

    # ------------------------------------------------------- KV transport

    def put_bytes(self, key: str, value: bytes) -> None:
        if self._kv is None:
            raise RuntimeError("single-process context has no KV store")
        self._kv.key_value_set_bytes(key, value)

    def get_bytes(self, key: str,
                  timeout_ms: int = _DEFAULT_TIMEOUT_MS) -> bytes:
        if self._kv is None:
            raise RuntimeError("single-process context has no KV store")
        return self._kv.blocking_key_value_get_bytes(key, timeout_ms)

    def delete(self, key: str) -> None:
        if self._kv is not None:
            self._kv.key_value_delete(key)

    def barrier(self, name: str,
                timeout_ms: int = _DEFAULT_TIMEOUT_MS) -> None:
        if self._kv is not None:
            self._kv.wait_at_barrier(name, timeout_ms)


def initialize(topo: Topology | None = None) -> RuntimeContext:
    """Bootstrap this process into the runtime described by ``topo``
    (default: ``Topology.from_env()``).

    Single-process topologies return a storeless context without touching
    ``jax.distributed`` at all. Multi-process topologies call
    ``jax.distributed.initialize`` (idempotent per process: a second call
    returns the existing client) — process 0 hosts the coordinator service
    at ``topo.coordinator``.
    """
    topo = topo or Topology.from_env()
    if topo.n_processes == 1:
        return RuntimeContext(topo)
    import jax

    from jax._src import distributed as _jdist

    if _jdist.global_state.client is None:
        jax.distributed.initialize(
            coordinator_address=topo.coordinator,
            num_processes=topo.n_processes,
            process_id=topo.process_id,
        )
    client = _jdist.global_state.client
    if client is None:  # pragma: no cover - initialize() raises first
        raise RuntimeError("jax.distributed.initialize produced no client")
    return RuntimeContext(topo, kv_client=client)


def shutdown() -> None:
    """Tear down this process's ``jax.distributed`` membership (no-op when
    never initialized). Spawned workers call this on exit so the coordinator
    sees a clean departure instead of a timeout."""
    from jax._src import distributed as _jdist

    if _jdist.global_state.client is not None:
        import jax

        jax.distributed.shutdown()


def free_port() -> int:
    """A free localhost TCP port (bind-to-0 trick; raceable in principle,
    fine for test/CI spawners)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _child_main(worker, process_id: int, n_processes: int, coordinator: str,
                local_devices: int, conn, args: tuple) -> None:
    """Spawned-child entry: pin env BEFORE jax creates a backend, join the
    runtime, run the worker, ship the (pickled) result back."""
    os.environ[ENV_COORDINATOR] = coordinator
    os.environ[ENV_NUM_PROCESSES] = str(n_processes)
    os.environ[ENV_PROCESS_ID] = str(process_id)
    os.environ[ENV_LOCAL_DEVICES] = str(local_devices)
    os.environ["JAX_PLATFORMS"] = "cpu"
    if local_devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={local_devices}"
        ).strip()
    try:
        ctx = initialize(Topology.from_env())
        try:
            out = worker(ctx, *args)
        finally:
            shutdown()
        conn.send(("ok", out))
    except BaseException:
        conn.send(("err", traceback.format_exc()))
    finally:
        conn.close()


def spawn_local(worker, n_processes: int, *, args: tuple = (),
                local_devices: int = 1, timeout_s: float = 300.0) -> list:
    """Run ``worker(ctx, *args)`` in ``n_processes`` fresh local CPU
    processes wired into one runtime; returns ``[worker result] * n`` in
    process order.

    ``worker`` must be a module-level (picklable) function: the spawn
    context starts clean interpreters, which is exactly what lets each child
    own its jax runtime (the parent's backend state never leaks in).
    Children talk to a coordinator hosted by child 0 on a free localhost
    port. Raises RuntimeError carrying the child tracebacks on any failure.
    """
    if n_processes < 1:
        raise ValueError(f"n_processes must be >= 1, got {n_processes}")
    coordinator = f"127.0.0.1:{free_port()}"
    mp = multiprocessing.get_context("spawn")
    procs, conns = [], []
    for i in range(n_processes):
        parent_conn, child_conn = mp.Pipe(duplex=False)
        p = mp.Process(
            target=_child_main,
            args=(worker, i, n_processes, coordinator, local_devices,
                  child_conn, tuple(args)),
            daemon=False,
        )
        p.start()
        child_conn.close()
        procs.append(p)
        conns.append(parent_conn)

    results, errors = [None] * n_processes, []
    try:
        for i, (p, conn) in enumerate(zip(procs, conns)):
            if conn.poll(timeout_s):
                status, payload = conn.recv()
                if status == "ok":
                    results[i] = payload
                else:
                    errors.append(f"[process {i}]\n{payload}")
            else:
                errors.append(f"[process {i}] no result within {timeout_s}s")
    finally:
        for p in procs:
            p.join(timeout=30)
            if p.is_alive():
                p.terminate()
                p.join(timeout=10)
    if errors:
        raise RuntimeError(
            f"spawn_local: {len(errors)}/{n_processes} workers failed:\n"
            + "\n".join(errors)
        )
    return results
