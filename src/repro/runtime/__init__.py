"""repro.runtime — the multi-host hierarchical aggregation runtime.

Takes the reproduction beyond one process (docs/DESIGN.md §11): ``launch``
bootstraps ``jax.distributed`` from a process-topology descriptor (and
``spawn_local`` forks CPU processes so CI exercises the whole runtime
without TPUs); ``hierarchy`` assigns clients to pods and runs the two-level
decode — pod-local correlation-aware sub-decode, then a cross-pod mean of
d-sized decoded estimates; ``comms`` moves the per-pod records between
processes and models the two-tier (intra-pod ICI / cross-pod DCN) byte
ledger; ``workers`` holds the picklable entry points subprocess tests and
benchmarks spawn.

Drive it through ``fl.run_rounds`` with
``RoundConfig(hierarchy="hier", pods=P, runtime=ctx)`` or from the CLI via
``python -m repro.fl.run --hosts 2 --pods 2``.
"""
from .comms import CrossPodExchange, cross_pod_traffic, psum_scatter_mean  # noqa: F401
from .hierarchy import (  # noqa: F401
    HierarchicalAggregator,
    PodPlan,
    combine_records,
    combine_rho,
)
from .launch import (  # noqa: F401
    RuntimeContext,
    Topology,
    free_port,
    initialize,
    shutdown,
    spawn_local,
)
