"""Deterministic synthetic LM data pipeline.

The container is offline, so the pipeline synthesises token streams with
learnable structure: a fixed random bigram transition table (per vocab
bucket) + a slowly-repeating motif, which gives a CE that falls measurably
below log(V) within a few hundred steps — enough signal for the end-to-end
training examples and the DME convergence comparisons.

Determinism/restart: batch(step) is a pure function of (seed, step, client),
so a restarted job resumes mid-stream with no data loss or duplication
(checkpointing only stores the step counter). Non-IID mode skews each
client's token marginal (paper App. D: label-sorted shards) so cross-client
gradient correlation R drops — visible in the estimator benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch: int              # per-client batch when n_clients > 0
    n_clients: int = 0      # 0 => no client axis
    seed: int = 0
    non_iid: float = 0.0    # 0 = IID; 1 = fully client-skewed marginals
    embed_dim: int = 0      # >0 => "embeddings" input mode (VLM/audio stubs)

    def _tokens(self, key, shape):
        """Markov-ish stream: mixture of bigram-determined and uniform."""
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.randint(k1, shape[:-1] + (1,), 0, self.vocab_size)
        steps = jax.random.randint(k2, shape, 1, 17)  # deterministic stride walk
        walk = (base + jnp.cumsum(steps, axis=-1)) % self.vocab_size
        noise = jax.random.randint(k3, shape, 0, self.vocab_size)
        pick = jax.random.bernoulli(jax.random.fold_in(key, 7), 0.15, shape)
        return jnp.where(pick, noise, walk).astype(jnp.int32)

    def _skew(self, tokens, client_id):
        if self.non_iid <= 0:
            return tokens
        # fold each client's tokens into its own vocab band (a plain shift
        # mod V is measure-preserving on near-uniform marginals: no skew)
        width = max(self.vocab_size // max(self.n_clients, 1), 1)
        band = (client_id * width) % self.vocab_size
        skewed = band + tokens % width
        take = self.non_iid
        mix = jax.random.bernoulli(
            jax.random.fold_in(jax.random.key(self.seed ^ 0x5EED), client_id),
            take, tokens.shape,
        )
        return jnp.where(mix, skewed, tokens)

    def batch_at(self, step: int):
        """Pure function of step -> batch dict (jit-friendly)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        s = self.seq_len + 1

        def one_client(cid):
            ck = jax.random.fold_in(key, cid)
            toks = self._tokens(ck, (self.batch, s))
            toks = self._skew(toks, cid)
            return toks

        if self.n_clients > 0:
            toks = jax.vmap(one_client)(jnp.arange(self.n_clients))
        else:
            toks = one_client(0)
        inputs, labels = toks[..., :-1], toks[..., 1:]
        if self.embed_dim > 0:
            table = jax.random.normal(
                jax.random.key(self.seed ^ 0xE3BED), (self.vocab_size, self.embed_dim)
            ) * 0.05
            inputs = jnp.take(table, inputs, axis=0)
        return {"inputs": inputs, "labels": labels}


def make_batch_iterator(spec: SyntheticLM, start_step: int = 0):
    step = start_step
    fn = jax.jit(spec.batch_at)
    while True:
        yield step, fn(step)
        step += 1
