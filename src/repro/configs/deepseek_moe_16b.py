"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) vocab=102400 —
fine-grained MoE: layer 0 dense (d_ff=10944), layers 1..27 with 64 routed
experts (d_ff=1408) top-6 + 2 shared experts. [arXiv:2401.06066]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=10944,          # dense prologue FFN width
        vocab_size=102400,
        rope_theta=1e4,
        prologue=(LayerSpec("attn", 0, "dense"),),
        block_pattern=(LayerSpec("attn", 0, "moe"),),
        n_blocks=27,
        n_experts=64,
        n_shared_experts=2,
        top_k_experts=6,
        d_ff_expert=1408,
        act="silu",
        supports_long_context=False,
    )
