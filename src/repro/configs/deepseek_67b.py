"""deepseek-67b [dense]: 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch. [arXiv:2401.02954]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=22016,
        vocab_size=102400,
        rope_theta=1e4,
        block_pattern=(LayerSpec("attn", 0, "dense"),),
        n_blocks=95,
        act="silu",
        supports_long_context=False,
    )
