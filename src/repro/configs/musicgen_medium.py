"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens. The EnCodec frontend is a
STUB: input_specs() feeds token ids over the 2048-codeword codebook
(one stream; the 4-codebook interleave is a data-pipeline detail).
[arXiv:2306.05284]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab_size=2048,
        rope_theta=1e4,
        block_pattern=(LayerSpec("attn", 0, "dense"),),
        n_blocks=48,
        act="gelu",  # plain (non-gated) FFN
        supports_long_context=False,
    )
