"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch. [arXiv:2401.14196]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=19200,
        vocab_size=32256,
        rope_theta=1e5,
        block_pattern=(LayerSpec("attn", 0, "dense"),),
        n_blocks=62,
        act="silu",
        supports_long_context=False,
    )
