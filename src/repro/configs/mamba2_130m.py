"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality), d_inner=1536, headdim=64,
tied embeddings. [arXiv:2405.21060]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        d_model=768,
        vocab_size=50280,
        block_pattern=(LayerSpec("mamba", 0, "none"),),
        n_blocks=24,
        d_state=128,
        mamba_d_inner=1536,
        mamba_headdim=64,
        mamba_ngroups=1,
        mamba_chunk=256,
        tie_embeddings=True,
        supports_long_context=True,  # recurrent state: O(1) per decoded token
    )
