"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 — Jamba period-8 block:
1 attention (32H GQA kv=8) : 7 mamba, MoE (16 experts top-2, d_ff=14336) on
odd layers, dense FFN (14336) on even layers. Mamba sublayers: d_inner=8192,
d_state=16. [arXiv:2403.19887]

NOTE (docs/DESIGN.md §7): Jamba uses Mamba-1 sublayers; we realise them with the
Mamba2/SSD block at matching (d_inner, d_state) — same interface and
asymptotics, documented simplification.
"""
from ..models.common import LayerSpec, ModelConfig


def _block():
    out = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append(LayerSpec(kind, 0, ffn))
    return tuple(out)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=65536,
        rope_theta=1e4,
        block_pattern=_block(),
        n_blocks=4,
        n_experts=16,
        top_k_experts=2,
        d_ff_expert=14336,
        d_state=16,
        mamba_d_inner=8192,
        mamba_headdim=64,
        mamba_ngroups=1,
        mamba_chunk=256,
        act="silu",
        supports_long_context=True,  # 28/32 layers recurrent; 4 attn layers
    )
