"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) vocab=32768 —
8 experts top-2 (d_ff=16384), sliding-window attention (per assignment).
[arXiv:2401.04088]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        vocab_size=32768,
        rope_theta=1e6,
        block_pattern=(LayerSpec("attn", 4096, "moe"),),
        n_blocks=56,
        n_experts=8,
        top_k_experts=2,
        d_ff_expert=16384,
        act="silu",
        # SWA everywhere -> KV cache bounded by the window; long_500k runs.
        supports_long_context=True,
    )
