"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — Yi-34B-style LM backbone; the anyres vision tower is a STUB:
input_specs() provides precomputed patch embeddings concatenated with text
embeddings (input_mode="embeddings"). [hf:llava-hf/llava-v1.6-34b]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5e6,
        block_pattern=(LayerSpec("attn", 0, "dense"),),
        n_blocks=60,
        act="silu",
        input_mode="embeddings",
        supports_long_context=False,
    )
