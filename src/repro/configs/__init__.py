"""Assigned-architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import dataclasses

from ..models.common import LayerSpec, ModelConfig
from . import (
    deepseek_67b,
    deepseek_coder_33b,
    deepseek_moe_16b,
    gemma3_4b,
    jamba_v0_1_52b,
    llava_next_34b,
    mamba2_130m,
    mixtral_8x22b,
    musicgen_medium,
    qwen1_5_32b,
)

_MODULES = {
    "qwen1.5-32b": qwen1_5_32b,
    "deepseek-67b": deepseek_67b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "gemma3-4b": gemma3_4b,
    "musicgen-medium": musicgen_medium,
    "deepseek-moe-16b": deepseek_moe_16b,
    "mixtral-8x22b": mixtral_8x22b,
    "llava-next-34b": llava_next_34b,
    "mamba2-130m": mamba2_130m,
    "jamba-v0.1-52b": jamba_v0_1_52b,
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list(ARCHS)}")
    return _MODULES[name].config()


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Structurally-identical tiny config for CPU smoke tests."""

    def small_spec(s: LayerSpec) -> LayerSpec:
        return dataclasses.replace(s, window=min(s.window, 8) if s.window else 0)

    kw = dict(
        d_model=64,
        vocab_size=512,
        n_blocks=min(cfg.n_blocks, 2),
        prologue=tuple(small_spec(s) for s in cfg.prologue),
        epilogue=tuple(small_spec(s) for s in cfg.epilogue[:1]),
        block_pattern=tuple(small_spec(s) for s in cfg.block_pattern),
        attn_kv_block=16,
        vocab_pad_multiple=16,
        remat="none",
        dtype="float32",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4, d_head=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.n_experts:
        kw.update(
            n_experts=min(cfg.n_experts, 8),
            top_k_experts=min(cfg.top_k_experts, 2),
            d_ff_expert=32,
            n_shared_experts=min(cfg.n_shared_experts, 1),
        )
    if cfg.mamba_d_inner:
        kw.update(mamba_d_inner=128, mamba_headdim=32, d_state=16, mamba_chunk=8)
    return cfg.replace(**kw)
