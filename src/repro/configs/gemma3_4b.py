"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
— 5:1 local(sliding 1024):global interleave, 128k context, tied embeddings.
[hf:google/gemma-3-4b-pt]"""
from ..models.common import LayerSpec, ModelConfig

_LOCAL = LayerSpec("attn", 1024, "dense")
_GLOBAL = LayerSpec("attn", 0, "dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=10240,
        vocab_size=262144,
        rope_theta=1e6,
        block_pattern=(_LOCAL,) * 5 + (_GLOBAL,),
        n_blocks=5,
        epilogue=(_LOCAL,) * 4,  # 34 = 5*6 + 4
        act="silu",
        tie_embeddings=True,
        # 5/6 of layers have a bounded (1024) cache; long_500k runs (DESIGN §6)
        supports_long_context=True,
    )
