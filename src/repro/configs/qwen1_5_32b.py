"""qwen1.5-32b [dense]: 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5-32B]"""
from ..models.common import LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,
        d_head=128,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        block_pattern=(LayerSpec("attn", 0, "dense"),),
        n_blocks=64,
        act="silu",
        supports_long_context=False,  # pure full attention -> long_500k skipped
    )
