"""Distribution layer: sharded compressed-mean collectives, GSPMD placement
rules, and pipeline parallelism.

Modules:
    collectives — cross-client compressed-mean (the paper's DME as a
                  collective): chunked encode at each client, decode at the
                  server (replicated, or owner-sharded via chunk ownership),
                  payload/byte accounting incl. intra-pod traffic columns,
                  error-feedback residuals.
    sharding    — divisibility-aware parameter / cache / batch placement over
                  (pod, data, model) meshes, plus the chunk-ownership plans
                  the sharded server decode partitions by.
    pipeline    — layer-pipelined application (GPipe schedule) over a mesh
                  axis.
"""
from . import collectives, pipeline, sharding  # noqa: F401
