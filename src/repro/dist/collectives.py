"""Compressed-mean collectives: the paper's DME as a cross-client gradient
exchange.

``compressed_mean_tree`` is the reference (GSPMD) path: ravel each client's
pytree, chunk to ``d_block`` (core.chunking), run the codec pipeline's
encode at every client (sparsifier + quantizer stages + error-feedback
residuals), decode the cross-client mean once at the "server", and unravel
back to the tree. Only the encoded payloads are notionally transmitted;
``info`` carries the exact byte accounting, read straight off the payload's
self-described ledger (``payload.meta`` — Konecny & Richtarik 2016-style
accuracy-vs-communication bookkeeping).

``compressed_mean_tree_shardmap`` is the explicit-collective path: clients
live on mesh ``client_axes``; each shard encodes its local clients' chunks,
payloads cross the wire via ``all_gather`` (payload-sized traffic — the
whole point of the estimator), and every shard decodes the identical mean.

Both entry points accept any codec-like object — a ``codec.Pipeline`` or a
bare sparsifier config (normalised via ``codec.as_pipeline``).

Error feedback (an ``ErrorFeedback`` stage in the pipeline): residual
buffers are (n_clients, C, d_block) chunk arrays threaded by the caller
(train_state["ef"] / ``ClientState.ef`` rows); the residual is rebuilt from
the pipeline's self-decode so its support is exactly the untransmitted
coordinates. On the shard_map path each residual row lives with its client's
shard (P(client_axes, None, None)) — no residual state ever crosses the
wire.

Partial participation (``participants``): a concrete (host-side) index array
naming the clients that actually report this round (repro.fl samples these).
Only participants encode/transmit; the decode re-derives THEIR randomness via
``client_ids`` and normalises by the actual participant count — never by the
sampled count (straggler renormalisation). Non-participants' EF residuals
carry over unchanged.

Overlapped collectives (``overlap=True``): both entry points can stream the
chunk axis through a double buffer — the encode of chunk tile c+1 is
enqueued (and, on an async backend, runs) while tile c's payload is in
flight / decoding, instead of encoding all C chunks, then decoding all C
chunks. On the shard_map path the per-tile ``all_gather`` IS the in-flight
payload, so encode genuinely overlaps cross-client traffic. The streamed
path is bit-identical to the synchronous one (asserted by
tests/test_async.py on all three fl backends); it therefore requires a
``chunk_streamable`` pipeline — per-chunk randomness independent of chunk
position (see ``codec.Pipeline.chunk_streamable``) — and raises otherwise
rather than silently changing the estimate.

Sharded server decode (``ownership=``, docs/DESIGN.md §10): a
``dist.sharding.ChunkOwnership`` plan assigns each mesh shard a contiguous
slice of the chunk grid. Instead of all-gathering EVERY per-client payload to
EVERY shard (server memory and intra-pod receive traffic O(n * k) per shard),
payloads for chunk c are routed only to c's owner (an ``all_to_all`` over
the client axes — reduce-scatter-style), the owner runs the codec decode for
its slice at its global chunk offset, and the global mean is assembled with
ONE ``all_gather`` of decoded means (d bytes per chunk, not n*k payload
bytes). Bit-identical to the unsharded decode for every ``decode_shardable``
pipeline (per-chunk decode reads only its own payload rows + its global
position — everything except ``rand_k_spatial(r_mode='est')``, whose online
R-hat pools statistics across chunks), with one float-level exception:
``rand_proj_spatial(r_mode='est')`` is decode-shardable (its R-hat is
per-chunk) but its einsum associates differently per slice width, so
est-mode parity is numerical rather than bitwise. ``info`` gains the
modelled ``intra_pod_bytes`` columns; at n_shards >= 2 the ownership route
strictly reduces intra-pod traffic whenever the remote clients' payload
bytes exceed the decoded vector's d bytes (asserted in tests + benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import obs
from ..core import chunking
from ..core.codec import as_pipeline
from . import sharding as shard_lib


@dataclasses.dataclass(frozen=True)
class DmeShardings:
    """Sharding constraints for the GSPMD compressed-mean path: the leading
    (client) axis of chunk/payload arrays lives on ``client_axes``."""

    mesh: Any
    client_axes: tuple

    def constrain(self, x):
        spec = P(self.client_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def constrain_tree(self, tree):
        return jax.tree.map(self.constrain, tree)


def dme_shardings(mesh, client_axes=("pod",)) -> DmeShardings | None:
    if mesh is None:
        return None
    axes = tuple(a for a in client_axes if a in mesh.axis_names)
    if not axes:
        return None
    return DmeShardings(mesh=mesh, client_axes=axes)


def _client_slice(tree, i):
    return jax.tree.map(lambda leaf: leaf[i], tree)


def _chunk_clients(tree, d_block: int):
    """Per-client ravel+chunk. tree leaves carry a leading client axis n.

    Returns (chunks (n, C, d_block), restore_fn for a single client, n).
    """
    n = jax.tree.leaves(tree)[0].shape[0]
    _, restore = chunking.tree_chunk(_client_slice(tree, 0), d_block)
    chunks = jax.vmap(
        lambda i: chunking.tree_chunk(_client_slice(tree, i), d_block)[0]
    )(jnp.arange(n))
    return chunks, restore, n


def _info(pipe, n: int, d_flat: int, n_chunks: int, n_total: int | None = None,
          n_shards: int = 1, plan=None) -> dict:
    # declared ledger from the payload schema; the ledger-honesty tests pin
    # it to the actual array bytes, so declared == transmitted.
    per_client = pipe.payload_nbytes(n_chunks)
    return {
        "n_clients": n,
        "n_total": n if n_total is None else n_total,  # rows in the input tree
        "n_chunks": n_chunks,
        "d_flat": d_flat,
        "d_block": pipe.d_block,
        "full_bytes": d_flat * 4,  # uncompressed float32 exchange baseline
        "payload_bytes_per_client": per_client,
        "bytes_sent": per_client * n,
        **intra_pod_traffic(pipe, n, n_chunks, n_shards, plan=plan),
    }


def intra_pod_traffic(pipe, n: int, n_chunks: int, n_shards: int,
                      plan=None) -> dict:
    """Modelled server-side (intra-pod) RECEIVE bytes of one decode, summed
    over all shards — the quantity the sharded decode exists to cut:

    - ``intra_pod_bytes_allgather``: the replicated decode all-gathers every
      remote client's full payload to every shard:
      ``n_shards * n_remote * payload_nbytes(n_chunks)``.
    - ``intra_pod_bytes_ownership``: the ownership route delivers each shard
      only its owned chunk slice (``all_to_all``), then assembles decoded
      means (d_block float32 bytes per chunk) with one ``all_gather``:
      ``n_shards * n_remote * payload_nbytes(chunks_per_owner)
      + n_shards * (n_shards - 1) * chunks_per_owner * d_block * 4``.
    - ``intra_pod_bytes``: the column for the route actually taken
      (``ownership`` when a plan is in force, else ``allgather``).

    ``n_remote = n - n/n_shards`` is the clients whose payloads must cross a
    shard boundary to reach one given shard. At ``n_shards == 1`` everything
    is shard-local and all columns are 0. The ownership column counts the
    PADDED slice width (what ``all_to_all`` actually moves).
    """
    if n_shards <= 1:
        return {
            "n_shards": max(1, n_shards),
            "intra_pod_bytes_allgather": 0,
            "intra_pod_bytes_ownership": 0,
            "intra_pod_bytes": 0,
        }
    n_remote = n - n / n_shards
    allgather = n_shards * n_remote * pipe.payload_nbytes(n_chunks)
    eff = plan if plan is not None else shard_lib.chunk_ownership(n_chunks, n_shards)
    cpo = eff.chunks_per_owner
    ownership = (
        n_shards * n_remote * pipe.payload_nbytes(cpo)
        + n_shards * (n_shards - 1) * cpo * pipe.d_block * 4
    )
    return {
        "n_shards": n_shards,
        "intra_pod_bytes_allgather": int(round(allgather)),
        "intra_pod_bytes_ownership": int(round(ownership)),
        "intra_pod_bytes": int(round(ownership if plan is not None else allgather)),
    }


def intra_pod_reduction(info: dict) -> float | None:
    """allgather/ownership server-side traffic ratio from an ``info`` dict
    (``compressed_mean_tree*`` or ``intra_pod_traffic``). > 1 means the
    sharded decode receives fewer bytes than the replicated all-gather
    decode. None when the decode ran on a single shard (nothing crosses a
    shard boundary either way)."""
    own = info.get("intra_pod_bytes_ownership", 0)
    ag = info.get("intra_pod_bytes_allgather", 0)
    if not own or not ag:
        return None
    return ag / own


def ownership_plan(ownership, n_chunks: int, n_shards: int):
    """Normalise the ``ownership=`` argument: None/False -> no plan;
    True -> plan over ``n_shards``; int -> plan over that many shards;
    a ``ChunkOwnership`` -> validated pass-through."""
    if ownership is None or ownership is False:
        return None
    if isinstance(ownership, shard_lib.ChunkOwnership):
        if ownership.n_chunks != n_chunks:
            raise ValueError(
                f"ownership plan covers {ownership.n_chunks} chunks but the "
                f"payload grid has {n_chunks}"
            )
        return ownership
    if ownership is True:
        return shard_lib.chunk_ownership(n_chunks, max(1, n_shards))
    return shard_lib.chunk_ownership(n_chunks, int(ownership))


def _participant_ids(participants, n_total: int) -> np.ndarray:
    """Normalise a participation mask/index list to a concrete id array."""
    p = np.asarray(participants)
    if p.dtype == bool:
        p = np.flatnonzero(p)
    if p.size == 0:
        raise ValueError("participation mask selects zero clients")
    if p.max() >= n_total or p.min() < 0:
        raise ValueError(f"participant id out of range [0, {n_total})")
    return p.astype(np.int32)


def check_streamable(pipe) -> None:
    """Raise unless ``pipe`` may stream the chunk axis (``overlap=True``),
    naming the offending stage so the caller knows what to change."""
    offender = pipe.non_streamable_stage
    if offender is not None:
        stage, reason = offender
        raise ValueError(
            "overlap=True needs a chunk-streamable pipeline (per-chunk "
            "randomness independent of chunk position), but stage "
            f"{type(stage).__name__} of {pipe.describe()!r} {reason}. "
            "Run it with overlap=False instead."
        )


def check_shardable(pipe) -> None:
    """Raise unless ``pipe`` may decode owner-sliced (``ownership=``),
    naming the offending stage. Weaker than ``check_streamable``: clients
    always encode full vectors, only the DECODE must be chunk-local."""
    offender = pipe.non_shardable_stage
    if offender is not None:
        stage, reason = offender
        raise ValueError(
            "ownership= needs a decode-shardable pipeline (per-chunk decode "
            "reading only its own payload rows), but stage "
            f"{type(stage).__name__} of {pipe.describe()!r} {reason}. "
            "Run it without ownership instead."
        )


def stream_tiles(n_chunks: int, tile: int = 1, ownership=None) -> list:
    """Chunk-axis tiling for the double-buffered stream: [(lo, hi), ...].

    With an ``ownership`` plan the tiling becomes OWNER-LOCAL: tiles never
    span an owner boundary, so each tile's decode runs wholly on one owner
    and ``overlap=`` composes with the sharded decode. Owner slices are
    contiguous and ascending, so the tiles still cover [0, n_chunks) in
    natural order.
    """
    if tile < 1:
        raise ValueError(f"overlap_tile must be >= 1, got {tile}")
    if ownership is None:
        return [(lo, min(lo + tile, n_chunks)) for lo in range(0, n_chunks, tile)]
    tiles = []
    for s in range(ownership.n_shards):
        lo, hi = ownership.slice_for(s)
        tiles.extend((l0, min(l0 + tile, hi)) for l0 in range(lo, hi, tile))
    return tiles


def sharded_decode(pipe, key, payloads, n: int, plan, *, client_ids=None):
    """Owner-partitioned server decode of a stacked payload (leading client
    axis): decode each owner's chunk slice at its global offset and
    concatenate. This is the decode the shard_map ownership path runs
    per-owner; here all owners run in ONE batched (vmapped) decode call — the
    chunk axis is padded to ``plan.padded_chunks`` and reshaped owner-major,
    so every owner decodes an equal-width slice and no per-owner Python loop
    (or per-owner compilation) remains. Padded tail chunks decode from
    all-zero payloads (every registered codec maps them to finite values;
    the fused rand_proj_spatial CG converges on them at iteration 0) and are
    dropped before returning. This makes the partition testable anywhere and
    serves the local/gspmd backends.

    Bit-identical to ``pipe.decode_payload(key, payloads, n)`` for every
    ``decode_shardable`` pipeline: per-chunk decode reads only its own
    payload rows, and position-keyed randomness is re-derived from the
    GLOBAL chunk id via ``chunk_offset``. Sole float-level exception:
    ``rand_proj_spatial(r_mode='est', decode_method='gram')`` — the gram
    R-hat einsum associates differently per slice width, so parity there is
    numerical (allclose), not bitwise (tests/test_ownership.py pins both
    contracts; the fused decode's R-hat is per-chunk elementwise and exact).
    """
    check_shardable(pipe)
    cpo = plan.chunks_per_owner
    pad = plan.padded_chunks - plan.n_chunks
    padded = payloads
    if pad:
        padded = jax.tree.map(
            lambda leaf: jnp.pad(leaf, [(0, 0), (0, pad)] + [(0, 0)] * (leaf.ndim - 2)),
            payloads,
        )
    tiles = jax.tree.map(
        lambda leaf: jnp.moveaxis(
            leaf.reshape(leaf.shape[0], plan.n_shards, cpo, *leaf.shape[2:]), 1, 0
        ),
        padded,
    )
    offsets = jnp.arange(plan.n_shards) * cpo

    def owner_decode(tile, lo):
        return pipe.decode_payload(key, tile, n, client_ids=client_ids,
                                   chunk_offset=lo)

    outs = jax.vmap(owner_decode)(tiles, offsets)  # (n_shards, cpo, d_block)
    return outs.reshape(plan.padded_chunks, *outs.shape[2:])[: plan.n_chunks]


def _double_buffer(tiles, produce, consume) -> list:
    """The overlap idiom, in one place: ``produce(tile c+1)`` is enqueued
    BEFORE ``consume`` of tile c — so on an async backend the next tile's
    encode runs while the previous tile's payload is in flight / decoding.
    Returns ``[consume(tile, produce(tile)) for tile in tiles]`` evaluated
    in that staggered order."""
    outs: list = []
    in_flight = None
    for t in tiles:
        entry = produce(t)
        if in_flight is not None:
            outs.append(consume(*in_flight))
        in_flight = (t, entry)
    outs.append(consume(*in_flight))
    return outs


def streamed_mean(pipe, key, x, n, *, client_ids=None, side_info=None,
                  tile: int = 1, need_self: bool = False, constrain=None,
                  ownership=None):
    """Double-buffered chunk streaming: encode tile c+1 while tile c decodes.

    ``x``: (n, C, d_block) chunk array (EF residual already added by the
    caller); ``side_info``: (C, d_block) broadcast side information — the
    tile's slice is subtracted before encode and added back after decode,
    exactly as ``Pipeline.encode``/``decode`` would. ``constrain`` optionally
    applies a sharding constraint to each tile's payload leaves.

    ``ownership`` (a ``ChunkOwnership`` plan) makes the tile iteration
    OWNER-LOCAL: tiles never span an owner's slice boundary and each tile is
    decoded at its global chunk offset, so the stream is exactly the decode
    an owner shard would run — ``overlap=`` composes with the sharded decode
    without changing a bit (streamable pipelines are position-free).

    Returns (mean (C, d_block), self_dec (n, C, d_block) | None). For
    chunk-streamable pipelines (validated here) the result is BIT-identical
    to the synchronous encode_all -> decode_payload: tiles only reorder
    work, never the numbers. The ordering is what buys the overlap — each
    tile's encode is enqueued before the previous tile's decode, so an async
    backend runs them concurrently while the payload is notionally on the
    wire.
    """
    check_streamable(pipe)
    if ownership is not None:
        check_shardable(pipe)
    n_chunks = x.shape[1]
    ids = jnp.arange(n) if client_ids is None else jnp.asarray(client_ids)

    def produce(t):
        lo, hi = t
        x_tile = x[:, lo:hi]
        if side_info is not None:
            x_tile = x_tile - side_info[None, lo:hi]
        payloads, _ = pipe.encode_all(key, x_tile, client_ids=ids)
        return payloads if constrain is None else constrain(payloads)

    def consume(t, payloads):
        lo, hi = t
        dec = pipe.decode_payload(key, payloads, n, client_ids=ids,
                                  chunk_offset=lo)
        if side_info is not None:
            dec = dec + side_info[lo:hi]
        self_dec = None
        if need_self:
            self_dec = jax.vmap(
                lambda i, p: pipe.self_decode(key, i, p)
            )(ids, payloads)
        return dec, self_dec

    drained = _double_buffer(stream_tiles(n_chunks, tile, ownership),
                             produce, consume)
    mean = jnp.concatenate([d for d, _ in drained], axis=0)
    self_dec = (
        jnp.concatenate([s for _, s in drained], axis=1) if need_self else None
    )
    return mean, self_dec


def compressed_mean_tree(spec, key, tree, shardings=None, ef_chunks=None,
                         participants=None, overlap=False, overlap_tile=1,
                         ownership=None):
    """Cross-client compressed mean of a pytree.

    tree leaves: (n_clients, ...). Returns (mean_tree, info, ef_next) where
    mean_tree drops the client axis, info is static byte/payload accounting,
    and ef_next is the updated (n, C, d_block) residual (None unless the
    pipeline has an ErrorFeedback stage).

    ``participants``: concrete index array / bool mask of reporting clients.
    Only they encode; decode uses their actual client ids and n = how many
    actually reported. ef_next keeps the FULL (n_clients, ...) shape — rows of
    non-participants carry over unchanged.

    ``ownership``: True / shard count / ``ChunkOwnership`` plan — run the
    server decode owner-partitioned (``sharded_decode``; on this GSPMD path
    the owners are logical, so the partition changes no numbers and no
    traffic, but the same slices and chunk offsets as the shard_map route
    are exercised and ``info`` reports the modelled ``intra_pod_bytes``
    columns at the plan's shard count).
    """
    pipe = as_pipeline(spec)
    chunks, restore, n_total = _chunk_clients(tree, pipe.d_block)
    n_chunks = chunks.shape[1]
    mesh_shards = 1
    if shardings is not None:
        for a in shardings.client_axes:
            mesh_shards *= shardings.mesh.shape[a]
    plan = ownership_plan(ownership, n_chunks, mesh_shards)
    if plan is not None:
        check_shardable(pipe)
    if participants is None:
        ids = None
        part_chunks, n = chunks, n_total
    else:
        ids = _participant_ids(participants, n_total)
        part_chunks, n = chunks[ids], len(ids)
    if shardings is not None:
        part_chunks = shardings.constrain(part_chunks)
    x = part_chunks
    if pipe.has_ef:
        if ef_chunks is None:
            ef_chunks = jnp.zeros_like(chunks)
        x = part_chunks + (ef_chunks if ids is None else ef_chunks[ids])

    if overlap:
        mean_chunks, self_dec = streamed_mean(
            pipe, key, x, n, client_ids=ids, tile=overlap_tile,
            need_self=pipe.has_ef,
            constrain=None if shardings is None else shardings.constrain_tree,
            ownership=plan,
        )
    else:
        # walltime spans on the round-phase tracks (timing/attribution only —
        # byte annotations stay with the fl driver, which owns the ledger)
        with obs.span("dist", "client_encode", track="client_encode",
                      clients=n):
            payloads, _ = pipe.encode_all(key, x, client_ids=ids)
        if shardings is not None:
            payloads = shardings.constrain_tree(payloads)
        with obs.span("dist", "owner_decode", track="owner_decode",
                      clients=n, sharded=plan is not None):
            if plan is not None:
                mean_chunks = sharded_decode(pipe, key, payloads, n, plan,
                                             client_ids=ids)
            else:
                mean_chunks = pipe.decode_payload(key, payloads, n,
                                                  client_ids=ids)
        self_dec = None
        if pipe.has_ef:
            id_arr = jnp.arange(n) if ids is None else jnp.asarray(ids)
            self_dec = jax.vmap(
                lambda i, p: pipe.self_decode(key, i, p)
            )(id_arr, payloads)
    mean_tree = restore(mean_chunks)

    ef_next = None
    if pipe.has_ef:
        resid = x - self_dec
        ef_next = resid if ids is None else ef_chunks.at[jnp.asarray(ids)].set(resid)

    d_flat = sum(
        int(np.prod(leaf.shape[1:], dtype=np.int64)) for leaf in jax.tree.leaves(tree)
    )
    n_shards = plan.n_shards if plan is not None else mesh_shards
    return mean_tree, _info(pipe, n, d_flat, n_chunks, n_total=n_total,
                            n_shards=n_shards, plan=plan), ef_next


def compressed_mean_tree_shardmap(spec, key, grads, mesh, param_pspecs=None,
                                  client_axes=("pod",), ef_chunks=None,
                                  participants=None, overlap=False,
                                  overlap_tile=1, ownership=None):
    """Explicit-collective compressed mean via shard_map.

    grads leaves: (n_clients, ...) with the client axis sharded over
    ``client_axes``. Each shard chunks + encodes its local clients, payloads
    are all-gathered across the client axes (the only payload-sized cross-
    client traffic), and every shard runs the identical server decode.
    Requires n_clients divisible by the client-axes extent; falls back to the
    GSPMD path otherwise.

    Error feedback (ErrorFeedback stage): ``ef_chunks`` (n, C, d_block) is
    sharded over the client axis, so each residual row lives with its
    client's shard and never crosses the wire; the updated residual returns
    with the same sharding. Parity with the GSPMD path is asserted by
    tests/test_error_feedback.py.

    ``participants``: concrete ids/mask of reporting clients. Every shard
    still encodes all its local clients (static shapes), but only the
    participants' payloads enter the decode (static gather on the replicated
    payload stack, with their actual client ids) and only their residual rows
    update.

    ``ownership`` (True / ``ChunkOwnership``; docs/DESIGN.md §10): the
    sharded server decode. Instead of all-gathering every payload to every
    shard, an ``all_to_all`` over the client axes routes each chunk's
    payloads ONLY to its owner shard (reduce-scatter-style: the payload
    chunk axis is split, the client axis concatenated), the owner decodes
    its slice at its global chunk offset, and the decoded means — d_block
    float32 bytes per chunk, not n*k payload bytes — are assembled with one
    ``all_gather``. Bit-identical to the unsharded decode (asserted in
    tests/test_ownership.py, incl. participants, heterogeneous budgets and
    EF; ``rand_proj_spatial(r_mode='est')`` is the one float-level-only
    case — see ``sharded_decode``); EF residuals still never cross the wire
    (self-decode runs on the client's own shard from its pre-routing
    payloads).
    """
    from jax.experimental.shard_map import shard_map

    pipe = as_pipeline(spec)
    client_axes = tuple(a for a in client_axes if a in mesh.axis_names)
    n = jax.tree.leaves(grads)[0].shape[0]
    n_shards = 1
    for a in client_axes:
        n_shards *= mesh.shape[a]
    if not client_axes or n % n_shards != 0:
        return compressed_mean_tree(
            pipe, key, grads, dme_shardings(mesh, client_axes),
            ef_chunks=ef_chunks, participants=participants,
            overlap=overlap, overlap_tile=overlap_tile, ownership=ownership,
        )
    if overlap:
        check_streamable(pipe)
    n_local = n // n_shards

    part_ids = None if participants is None else _participant_ids(participants, n)
    n_eff = n if part_ids is None else len(part_ids)
    part_mask = np.ones(n, bool)
    if part_ids is not None:
        part_mask = np.zeros(n, bool)
        part_mask[part_ids] = True

    template = _client_slice(grads, 0)
    _, restore = chunking.tree_chunk(template, pipe.d_block)
    d_flat = sum(
        int(np.prod(leaf.shape[1:], dtype=np.int64)) for leaf in jax.tree.leaves(grads)
    )
    n_chunks = chunking.num_chunks(d_flat, pipe.d_block)
    plan = ownership_plan(ownership, n_chunks, n_shards)
    if plan is not None:
        if plan.n_shards != n_shards:
            raise ValueError(
                f"ownership plan has {plan.n_shards} owners but the mesh "
                f"client axes {client_axes} hold {n_shards} shards"
            )
        check_shardable(pipe)
    if pipe.has_ef and ef_chunks is None:
        ef_chunks = jnp.zeros((n, n_chunks, pipe.d_block), jnp.float32)
    use_ef = pipe.has_ef

    def local_fn(key, g_local, ef_local):
        shard_idx = jnp.zeros((), jnp.int32)
        for a in client_axes:
            shard_idx = shard_idx * mesh.shape[a] + jax.lax.axis_index(a)
        ids = shard_idx * n_local + jnp.arange(n_local)
        chunks = jax.vmap(
            lambda i: chunking.tree_chunk(_client_slice(g_local, i), pipe.d_block)[0]
        )(jnp.arange(n_local))
        x = chunks + ef_local if use_ef else chunks

        def encode_local(x_cols):
            return jax.vmap(
                lambda i, c: pipe.encode_payload(key, i, c)
            )(ids, x_cols)

        def encode_and_gather(x_tile):
            payloads = encode_local(x_tile)
            gathered = jax.tree.map(
                lambda leaf: jax.lax.all_gather(
                    leaf, client_axes, axis=0, tiled=True
                ),
                payloads,
            )
            return payloads, gathered

        def route_to_owners(payloads):
            """The reduce-scatter-style payload routing: split the chunk axis
            across the client axes, concatenate the client axis — this shard
            receives ONLY the slice it owns, from every client."""
            return jax.tree.map(
                lambda leaf: jax.lax.all_to_all(
                    leaf, client_axes, split_axis=1, concat_axis=0, tiled=True
                ),
                payloads,
            )

        def decode_owned(routed, owner_lo):
            """This shard's server decode of its owned slice, at its global
            chunk offset (position-keyed codecs re-derive the full decode's
            randomness from it)."""
            if part_ids is None:
                return pipe.decode_payload(key, routed, n, chunk_offset=owner_lo)
            selected = jax.tree.map(lambda leaf: leaf[part_ids], routed)
            return pipe.decode_payload(key, selected, n_eff,
                                       client_ids=part_ids,
                                       chunk_offset=owner_lo)

        def decode_gathered(gathered):
            if part_ids is None:
                return pipe.decode_payload(key, gathered, n)
            selected = jax.tree.map(lambda leaf: leaf[part_ids], gathered)
            return pipe.decode_payload(key, selected, n_eff, client_ids=part_ids)

        def local_self_dec(payloads):
            return jax.vmap(
                lambda i, p: pipe.self_decode(key, i, p)
            )(ids, payloads)

        def pad_chunk_axis(tree_like, pad):
            if pad == 0:
                return tree_like
            return jax.tree.map(
                lambda leaf: jnp.pad(
                    leaf, [(0, 0), (0, pad)] + [(0, 0)] * (leaf.ndim - 2)
                ),
                tree_like,
            )

        def assemble(mean_own):
            """(chunks_per_owner, d_block) decoded slice -> replicated
            (n_chunks, d_block): ONE all_gather of d-sized means — the only
            post-routing cross-shard traffic."""
            full = jax.lax.all_gather(mean_own, client_axes, axis=0, tiled=True)
            return full[:n_chunks]

        if plan is not None:
            cpo = plan.chunks_per_owner
            owner_lo = shard_idx * cpo
            if not overlap:
                payloads = encode_local(x)
                routed = route_to_owners(pad_chunk_axis(payloads, plan.pad))
                mean_chunks = assemble(decode_owned(routed, owner_lo))
                if not use_ef:
                    return restore(mean_chunks), ef_local
                self_dec = local_self_dec(payloads)
            else:
                # owner-local tile streaming: tile t covers positions
                # [lo, hi) of EVERY owner's slice at once, so the per-tile
                # all_to_all is the in-flight payload and each owner decodes
                # its sub-tile while the next tile encodes.
                x_pad = jnp.pad(x, ((0, 0), (0, plan.pad), (0, 0)))
                tile_cols = [
                    np.concatenate(
                        [s * cpo + np.arange(lo, hi) for s in range(n_shards)]
                    )
                    for lo, hi in stream_tiles(cpo, overlap_tile)
                ]

                def produce(cols):
                    payloads = encode_local(x_pad[:, cols])
                    return payloads, route_to_owners(payloads)

                def consume(cols, e):
                    dec = decode_owned(e[1], owner_lo + cols[0])
                    return dec, local_self_dec(e[0]) if use_ef else None

                drained = _double_buffer(tile_cols, produce, consume)
                mean_chunks = assemble(
                    jnp.concatenate([m for m, _ in drained], axis=0)
                )
                if not use_ef:
                    return restore(mean_chunks), ef_local
                # tiles saw owner-major column order: invert the (static)
                # permutation to put the self-decodes back in natural order
                col_order = np.concatenate(tile_cols)
                self_cat = jnp.concatenate([s for _, s in drained], axis=1)
                self_dec = self_cat[:, np.argsort(col_order)][:, :n_chunks]
        elif not overlap:
            payloads, gathered = encode_and_gather(x)
            mean_chunks = decode_gathered(gathered)
            if not use_ef:
                return restore(mean_chunks), ef_local
            self_dec = local_self_dec(payloads)
        else:
            # the per-tile all_gather IS the in-flight payload here
            drained = _double_buffer(
                stream_tiles(n_chunks, overlap_tile),
                lambda t: encode_and_gather(x[:, t[0]:t[1]]),
                lambda t, e: (decode_gathered(e[1]),
                              local_self_dec(e[0]) if use_ef else None),
            )
            mean_chunks = jnp.concatenate([m for m, _ in drained], axis=0)
            if not use_ef:
                return restore(mean_chunks), ef_local
            self_dec = jnp.concatenate([s for _, s in drained], axis=1)

        # residual update stays on the client's shard; non-participants keep
        # their residual (they did not transmit this round)
        resid = x - self_dec
        local_part = jnp.take(jnp.asarray(part_mask), ids)
        ef_next = jnp.where(local_part[:, None, None], resid, ef_local)
        return restore(mean_chunks), ef_next

    if ef_chunks is None:  # dummy carried buffer keeps one code path
        ef_chunks = jnp.zeros((n, 1, 1), jnp.float32)
    client_spec = P(client_axes, None, None)
    in_specs = (
        P(),
        jax.tree.map(lambda leaf: P(client_axes, *([None] * (leaf.ndim - 1))), grads),
        client_spec,
    )
    mean_specs = jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)), template)
    # ``local_fn`` is traced by shard_map, so per-phase spans cannot live
    # inside it; the whole exchange gets one payload_route span (encode +
    # all_gather/all_to_all + decode run fused in the traced program)
    with obs.span("dist", "payload_route", track="payload_route",
                  backend="shard_map", shards=n_shards):
        mean_tree, ef_next = shard_map(
            local_fn, mesh, in_specs=in_specs,
            out_specs=(mean_specs, client_spec), check_rep=False,
        )(key, grads, ef_chunks)
    if not use_ef:
        ef_next = None

    return mean_tree, _info(pipe, n_eff, d_flat, n_chunks, n_total=n,
                            n_shards=n_shards, plan=plan), ef_next


def psum_scatter_mean(tiles, counts, mesh, axis: str = "pod"):
    """Count-weighted mean of pre-placed per-pod tiles via ``psum_scatter``.

    The cross-pod combine of the hierarchical decode (docs/DESIGN.md §11) as
    a real device collective: ``tiles`` is (P, C, d_block) with row p — pod
    p's decoded d-sized estimate — pre-placed on shard p of mesh ``axis``;
    ``counts`` is (P,) contributing client counts (0 marks an absent pod, a
    row whose values are then irrelevant). Each shard contributes
    ``counts[p] * tiles[p]``, a ``psum_scatter`` reduces the weighted sum
    while leaving each shard exactly 1/P of the chunk axis (DCN traffic
    (P-1)/P of the naive all-reduce), and one ``all_gather`` of the
    normalised slices replicates the mean:

        sum_p counts[p] * tiles[p] / sum_p counts[p]    (C, d_block)

    ``counts`` must sum to > 0. The chunk axis is padded to a multiple of P
    internally. On a 1-shard mesh this degenerates to the weighted mean with
    no collective traffic. The KV-store exchange in ``runtime.comms`` is the
    CPU-backend equivalent of this combine (multiprocess XLA collectives are
    unavailable there); on TPU/GPU meshes this is the fast path.
    """
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    tiles = jnp.asarray(tiles)
    counts = jnp.asarray(counts, tiles.dtype)
    if tiles.ndim != 3 or tiles.shape[0] != n_shards:
        raise ValueError(
            f"tiles must be (n_shards={n_shards}, C, d_block), got "
            f"{tiles.shape}"
        )
    if counts.shape != (n_shards,):
        raise ValueError(f"counts must be ({n_shards},), got {counts.shape}")
    n_chunks = tiles.shape[1]
    pad = (-n_chunks) % n_shards

    def local_fn(tile, cnt):
        contrib = cnt[0] * tile[0]  # (C, d_block), this shard's weighted row
        if pad:
            contrib = jnp.pad(contrib, ((0, pad), (0, 0)))
        part = jax.lax.psum_scatter(contrib, axis, scatter_dimension=0,
                                    tiled=True)
        total = jax.lax.psum(cnt[0], axis)
        full = jax.lax.all_gather(part / total, axis, axis=0, tiled=True)
        return full[:n_chunks]

    return shard_map(
        local_fn, mesh,
        in_specs=(P(axis, None, None), P(axis)),
        out_specs=P(None, None), check_rep=False,
    )(tiles, counts)
