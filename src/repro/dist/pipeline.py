"""Layer-pipelined application over a mesh axis (GPipe schedule).

``partition_blocks`` regroups a stacked-blocks param tree (n_blocks, ...)
into (n_stages, blocks_per_stage, ...); ``pipeline_apply`` runs the staged
blocks over microbatches with a shard_map: stage s holds its param shard,
activations hop stage-to-stage via ppermute, and the last stage's outputs
are broadcast back with a masked psum. Results are bit-identical to the
serial composition (the bubble only wastes compute, never reorders math).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def partition_blocks(tree, n_stages: int):
    """(n_blocks, ...) stacked params -> (n_stages, n_blocks//n_stages, ...)."""

    def one(leaf):
        nb = leaf.shape[0]
        if nb % n_stages:
            raise ValueError(f"n_blocks={nb} not divisible by n_stages={n_stages}")
        return leaf.reshape((n_stages, nb // n_stages) + leaf.shape[1:])

    return jax.tree.map(one, tree)


def pipeline_apply(stage_fn, staged, x, mesh, axis: str = "pipe"):
    """Apply staged blocks to microbatches x: (m, microbatch, ...).

    stage_fn(stage_params, h) applies one stage's blocks to activations h of
    shape x.shape[1:]. staged leaves: (n_stages, ...) sharded over ``axis``.
    Returns (m, microbatch, ...) — the serial composition of all stages.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]
    n_steps = m + n_stages - 1  # pipeline depth: fill + drain bubble

    def shard_fn(staged_local, x_all):
        params = jax.tree.map(lambda leaf: jnp.squeeze(leaf, 0), staged_local)
        stage = jax.lax.axis_index(axis)

        def body(t, carry):
            state, outputs = carry
            # stage 0 ingests microbatch t; later stages consume the hop
            mb = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, m - 1), 0, keepdims=False
            )
            h = stage_fn(params, jnp.where(stage == 0, mb, state))
            # last stage retires microbatch t - (n_stages - 1)
            j = t - (n_stages - 1)
            valid = jnp.logical_and(j >= 0, j < m)
            jc = jnp.clip(j, 0, m - 1)
            prev = jax.lax.dynamic_index_in_dim(outputs, jc, 0, keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(valid, h, prev), jc, 0
            )
            nxt = jax.lax.ppermute(
                h, axis, [(i, i + 1) for i in range(n_stages - 1)]
            )
            return nxt, outputs

        init = (jnp.zeros(x_all.shape[1:], x_all.dtype), jnp.zeros_like(x_all))
        _, outputs = jax.lax.fori_loop(0, n_steps, body, init)
        # only the last stage holds real outputs; broadcast via masked psum
        return jax.lax.psum(
            outputs * (stage == n_stages - 1).astype(outputs.dtype), axis
        )

    in_specs = (
        jax.tree.map(lambda leaf: P(axis, *([None] * (leaf.ndim - 1))), staged),
        P(*([None] * x.ndim)),
    )
    out_specs = P(*([None] * x.ndim))
    return shard_map(
        shard_fn, mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )(staged, x)
