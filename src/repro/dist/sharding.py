"""GSPMD placement rules over (pod, data, model) meshes.

One rule, applied uniformly from the single structural source of truth
(``transformer.param_defs``): every parameter names its logical axes, and
``spec_for`` maps logical axes to mesh axes with divisibility checks —
a non-divisible dimension falls through to replication instead of forcing
GSPMD to pad (padding shows up as rematerialisation all-gathers every layer;
see docs/EXPERIMENTS.md §Roofline).

Placement policy:
    - the "model" mesh axis goes to the first axis of ``model_pref`` present
      in the param whose dim is divisible by the model-axis size (tensor
      parallelism); ``MODEL_PREF_EP`` is the expert-parallel-first variant.
    - the "data" mesh axis goes to the "embed" axis when divisible (FSDP /
      ZeRO-3: params and optimizer moments are sharded over data too).
    - the "pod" axis is NEVER assigned to parameters: pods are DME clients
      holding full replicas whose gradient exchange is the compressed
      collective in ``dist.collectives``, not an all-reduce.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axes eligible for tensor parallelism, in assignment preference order
MODEL_PREF = ("heads", "mamba_inner", "ff", "vocab", "experts")
# expert-parallel-first variant (dryrun --knobs '{"ep_first": true}')
MODEL_PREF_EP = ("experts", "heads", "mamba_inner", "ff", "vocab")

# logical axes eligible for the data (FSDP) axis, in preference order
DATA_PREF = ("embed",)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension (pod-major)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def spec_for(shape, axes, mesh, *, model_pref=MODEL_PREF, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter from its logical axis names.

    ``axes`` is a tuple of logical names (or None), len == len(shape).
    """
    sizes = mesh_axis_sizes(mesh)
    model_size = sizes.get("model", 0)
    data_size = sizes.get("data", 0)
    assign: list = [None] * len(shape)

    if model_size > 1:
        for pref in model_pref:
            if pref in axes:
                i = axes.index(pref)
                if shape[i] % model_size == 0:
                    assign[i] = "model"
                    break
    if fsdp and data_size > 1:
        for pref in DATA_PREF:
            if pref in axes:
                i = axes.index(pref)
                if assign[i] is None and shape[i] % data_size == 0:
                    assign[i] = "data"
                    break
    return P(*assign)


def param_shardings(cfg, mesh, *, model_pref=MODEL_PREF, fsdp: bool = True):
    """NamedSharding pytree matching ``transformer.abstract_params(cfg)``."""
    from ..models import transformer

    defs = transformer.param_defs(cfg)
    return jax.tree.map(
        lambda d: NamedSharding(
            mesh, spec_for(d.shape, d.axes, mesh, model_pref=model_pref, fsdp=fsdp)
        ),
        defs,
        is_leaf=lambda x: isinstance(x, transformer.ParamDef),
    )


def cache_shardings(cfg, mesh, cache_abs, *, seq_shard: bool = False):
    """Decode-cache placement. Leaves are keyed by their dict name:

        k/v  (B, S, kvh, dh): batch -> DP, kv heads -> model if divisible
        pos  (B, S)
        conv (B, K, convdim):  convdim -> model if divisible
        ssm  (B, nh, N, hd):   ssm heads -> model if divisible

    ``seq_shard=True`` (long-context, batch ~ 1) shards the sequence dim of
    k/v/pos over the DP axes instead of the batch dim. Leaves under "blocks"
    carry a leading stacked-layers dim that is never sharded.
    """
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    model_size = sizes.get("model", 0)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]

    def one(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), None)
        off = 1 if "blocks" in keys else 0  # stacked-layers leading dim
        spec = [None] * leaf.ndim
        shape = leaf.shape
        if seq_shard and name in ("k", "v", "pos"):
            s_i = off + 1
            if dp and shape[s_i] % dp_size == 0:
                spec[s_i] = dp
        elif dp and shape[off] % dp_size == 0:
            spec[off] = dp
        if model_size > 1:
            if name in ("k", "v") and shape[off + 2] % model_size == 0:
                spec[off + 2] = "model"
            elif name == "conv" and shape[off + 2] % model_size == 0:
                spec[off + 2] = "model"
            elif name == "ssm" and shape[off + 1] % model_size == 0:
                spec[off + 1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_abs)
