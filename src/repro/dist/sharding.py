"""GSPMD placement rules over (pod, data, model) meshes.

One rule, applied uniformly from the single structural source of truth
(``transformer.param_defs``): every parameter names its logical axes, and
``spec_for`` maps logical axes to mesh axes with divisibility checks —
a non-divisible dimension falls through to replication instead of forcing
GSPMD to pad (padding shows up as rematerialisation all-gathers every layer;
see docs/EXPERIMENTS.md §Roofline).

Placement policy:
    - the "model" mesh axis goes to the first axis of ``model_pref`` present
      in the param whose dim is divisible by the model-axis size (tensor
      parallelism); ``MODEL_PREF_EP`` is the expert-parallel-first variant.
    - the "data" mesh axis goes to the "embed" axis when divisible (FSDP /
      ZeRO-3: params and optimizer moments are sharded over data too).
    - the "pod" axis is NEVER assigned to parameters: pods are DME clients
      holding full replicas whose gradient exchange is the compressed
      collective in ``dist.collectives``, not an all-reduce.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axes eligible for tensor parallelism, in assignment preference order
MODEL_PREF = ("heads", "mamba_inner", "ff", "vocab", "experts")
# expert-parallel-first variant (dryrun --knobs '{"ep_first": true}')
MODEL_PREF_EP = ("experts", "heads", "mamba_inner", "ff", "vocab")

# logical axes eligible for the data (FSDP) axis, in preference order
DATA_PREF = ("embed",)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# ------------------------------------------------------- chunk ownership plan


@dataclasses.dataclass(frozen=True)
class ChunkOwnership:
    """Contiguous chunk-grid partition for the sharded server decode.

    Shard s OWNS global chunks ``[s*chunks_per_owner, (s+1)*chunks_per_owner)``
    clamped to ``n_chunks``: payloads for chunk c are routed only to c's
    owner (``dist.collectives``, ``ownership=``), the owner decodes its slice,
    and the global mean is assembled from the decoded slices — so no shard
    ever materialises all payloads.

    The plan follows the same divisibility-first policy as ``spec_for``: when
    ``n_chunks % n_shards == 0`` the slices tile the grid exactly; otherwise
    the grid is logically padded to ``padded_chunks = n_shards *
    chunks_per_owner`` (the ``all_to_all`` payload routing needs equal
    splits), the padding chunks belong to the tail shard(s), carry all-zero
    payloads, and are dropped when the decoded mean is assembled.
    """

    n_chunks: int
    n_shards: int

    def __post_init__(self):
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")

    @property
    def chunks_per_owner(self) -> int:
        """Owned-slice width (ceil division: the tail may own fewer)."""
        return -(-self.n_chunks // self.n_shards)

    @property
    def padded_chunks(self) -> int:
        return self.n_shards * self.chunks_per_owner

    @property
    def pad(self) -> int:
        return self.padded_chunks - self.n_chunks

    def slice_for(self, shard: int) -> tuple[int, int]:
        """Global chunk slice [lo, hi) shard ``shard`` owns (hi == lo for a
        fully-padded tail shard)."""
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        lo = shard * self.chunks_per_owner
        return min(lo, self.n_chunks), min(lo + self.chunks_per_owner, self.n_chunks)

    @property
    def slices(self) -> tuple:
        return tuple(self.slice_for(s) for s in range(self.n_shards))

    def owner_of(self, chunk: int) -> int:
        if not 0 <= chunk < self.n_chunks:
            raise ValueError(f"chunk {chunk} out of range [0, {self.n_chunks})")
        return chunk // self.chunks_per_owner


def chunk_ownership(n_chunks: int, n_shards: int) -> ChunkOwnership:
    """Ownership plan for an ``n_chunks`` grid over ``n_shards`` mesh shards."""
    return ChunkOwnership(n_chunks=n_chunks, n_shards=n_shards)


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension (pod-major)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def spec_for(shape, axes, mesh, *, model_pref=MODEL_PREF, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter from its logical axis names.

    ``axes`` is a tuple of logical names (or None), len == len(shape).
    """
    sizes = mesh_axis_sizes(mesh)
    model_size = sizes.get("model", 0)
    data_size = sizes.get("data", 0)
    assign: list = [None] * len(shape)

    if model_size > 1:
        for pref in model_pref:
            if pref in axes:
                i = axes.index(pref)
                if shape[i] % model_size == 0:
                    assign[i] = "model"
                    break
    if fsdp and data_size > 1:
        for pref in DATA_PREF:
            if pref in axes:
                i = axes.index(pref)
                if assign[i] is None and shape[i] % data_size == 0:
                    assign[i] = "data"
                    break
    return P(*assign)


def param_shardings(cfg, mesh, *, model_pref=MODEL_PREF, fsdp: bool = True):
    """NamedSharding pytree matching ``transformer.abstract_params(cfg)``."""
    from ..models import transformer

    defs = transformer.param_defs(cfg)
    return jax.tree.map(
        lambda d: NamedSharding(
            mesh, spec_for(d.shape, d.axes, mesh, model_pref=model_pref, fsdp=fsdp)
        ),
        defs,
        is_leaf=lambda x: isinstance(x, transformer.ParamDef),
    )


def cache_shardings(cfg, mesh, cache_abs, *, seq_shard: bool = False):
    """Decode-cache placement. Leaves are keyed by their dict name:

        k/v  (B, S, kvh, dh): batch -> DP, kv heads -> model if divisible
        pos  (B, S)
        conv (B, K, convdim):  convdim -> model if divisible
        ssm  (B, nh, N, hd):   ssm heads -> model if divisible

    ``seq_shard=True`` (long-context, batch ~ 1) shards the sequence dim of
    k/v/pos over the DP axes instead of the batch dim. Leaves under "blocks"
    carry a leading stacked-layers dim that is never sharded.
    """
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    model_size = sizes.get("model", 0)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]

    def one(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), None)
        off = 1 if "blocks" in keys else 0  # stacked-layers leading dim
        spec = [None] * leaf.ndim
        shape = leaf.shape
        if seq_shard and name in ("k", "v", "pos"):
            s_i = off + 1
            if dp and shape[s_i] % dp_size == 0:
                spec[s_i] = dp
        elif dp and shape[off] % dp_size == 0:
            spec[off] = dp
        if model_size > 1:
            if name in ("k", "v") and shape[off + 2] % model_size == 0:
                spec[off + 2] = "model"
            elif name == "conv" and shape[off + 2] % model_size == 0:
                spec[off + 2] = "model"
            elif name == "ssm" and shape[off + 1] % model_size == 0:
                spec[off + 1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_abs)
