"""CLI entry point for federated round workloads.

    PYTHONPATH=src python -m repro.fl.run --task power_iteration \
        --estimator rand_proj_spatial --smoke

    # paper Fig. 3/4-style comparison (same keys => paired across estimators):
    PYTHONPATH=src python -m repro.fl.run --task dme --rho 0.95 --compare

    # temporal decoding on a slowly-drifting task (broadcast side info):
    PYTHONPATH=src python -m repro.fl.run --task drift --estimator \
        rand_proj_spatial --temporal

    # TRUE per-client Rand-k-Temporal (client-held memories in ClientState):
    PYTHONPATH=src python -m repro.fl.run --task drift --estimator rand_k \
        --client-temporal

    # async rounds: stragglers' late payloads admitted at staleness 1
    # instead of dropped (docs/DESIGN.md §9):
    PYTHONPATH=src python -m repro.fl.run --task drift --dropout 0.3 --async

Per-round lines report the task metric, the MSE against the survivors' true
mean, the cumulative payload-byte ledger, and (async) admitted stale
payloads; --compare prints an MSE-at-equal-bytes table across the baseline
estimator family.
"""
from __future__ import annotations

import argparse
import json
import math

import numpy as np

from .. import obs
from ..core import codec
from . import rounds as rounds_lib
from .clients import Cohort
from .tasks import get_task

COMPARE = [
    ("rand_k", dict()),
    ("rand_k_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="avg")),
    ("sparse_proj", dict(transform="avg")),
]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--task", default="power_iteration",
                    choices=["power_iteration", "kmeans", "linear_regression",
                             "logistic_regression", "dme", "drift"],
                    help="paper §5 workload or correlation-dialed synthetic")
    ap.add_argument("--estimator", default="rand_proj_spatial",
                    help="registered sparsifier name (codec.SPARSIFIERS)")
    ap.add_argument("--transform", default="avg",
                    help="one|max|avg|opt|wavg (wavg = online-R practical variant)")
    ap.add_argument("--rounds", type=int, default=20,
                    help="federated rounds to drive")
    ap.add_argument("--clients", type=int, default=10,
                    help="cohort size n")
    ap.add_argument("--k", type=int, default=0, help="0 => d_block // 10")
    ap.add_argument("--budget", default="manual", choices=["manual", "auto"],
                    help="auto => derive k from the Johnson-Lindenstrauss "
                         "bound via codec.suggest_budget(n_clients, --jl-eps, "
                         "d_block), overriding --k; raises "
                         "BudgetExceedsDimension when the bound does not fit")
    ap.add_argument("--jl-eps", dest="jl_eps", type=float, default=0.5,
                    help="JL distortion target for --budget auto")
    ap.add_argument("--d-block", type=int, default=0, help="0 => task dim (<=1024)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of the cohort sampled per round")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="P(sampled client misses the round deadline); sync "
                         "rounds drop these stragglers, --async admits them "
                         "late")
    ap.add_argument("--async", dest="async_rounds", action="store_true",
                    help="async rounds: don't wait for stragglers — buffer "
                         "their late payloads and admit them into the next "
                         "round's decode (staleness-1 aggregation)")
    ap.add_argument("--staleness", type=int, default=1, choices=[0, 1],
                    help="max admitted payload age under --async: 1 admits "
                         "late payloads next round, 0 drops them (scheduling-"
                         "only ablation)")
    ap.add_argument("--stale-weight", type=float, default=1.0,
                    help="per-client weight of an admitted stale payload "
                         "relative to a fresh one")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered chunk streaming: encode chunk c+1 "
                         "while chunk c's payload is in flight (bit-identical "
                         "to the sync decode)")
    ap.add_argument("--ownership", action="store_true",
                    help="sharded server decode: each owner shard receives "
                         "and decodes only the chunk slice it owns, then "
                         "decoded means are assembled (bit-identical; cuts "
                         "intra-pod traffic at >= 2 owners)")
    ap.add_argument("--owners", type=int, default=0,
                    help="owner shards for --ownership; 0 derives from the "
                         "mesh client axes (1 on plain CPU)")
    ap.add_argument("--temporal", action="store_true",
                    help="decode deltas against the server's previous estimate")
    ap.add_argument("--client-temporal", action="store_true",
                    help="true per-client temporal memories (codec.Temporal)")
    ap.add_argument("--ef", action="store_true",
                    help="error-feedback stage (residuals in ClientState)")
    ap.add_argument("--no-fused-kernels", dest="no_fused_kernels",
                    action="store_true",
                    help="escape hatch: decode rand_proj_spatial via the "
                         "unfused Gram-eigh path instead of the fused "
                         "matrix-free kernel fast path (docs/KERNELS.md); "
                         "no-op for estimators without a fused decode")
    ap.add_argument("--payload-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8", "correlated"],
                    help="quantizer stage appended to the pipeline "
                         "(correlated = anti-correlated int8 rounding offsets "
                         "from the shared round key; same wire bytes as int8)")
    ap.add_argument("--entropy-code", dest="entropy_code", action="store_true",
                    help="append the EntropyCode stage: the parallel "
                         "History.coded_bytes ledger charges the EXACT "
                         "entropy-coded stream length of each payload")
    ap.add_argument("--adaptive-budgets", dest="adaptive_budgets",
                    action="store_true",
                    help="rand_k only: rewrite each round's per-chunk budget "
                         "vector from the previous estimate's per-chunk norm "
                         "mass (docs/DESIGN.md §3.8)")
    ap.add_argument("--backend", default="local",
                    choices=["local", "gspmd", "shard_map"],
                    help="round execution backend (docs/API.md backend matrix)")
    ap.add_argument("--pods", type=int, default=1,
                    help=">= 2 turns on hierarchical aggregation "
                         "(docs/DESIGN.md §11): pod-local correlation-aware "
                         "sub-decode, then a cross-pod mean of decoded "
                         "estimates; 1 is the flat path (bitwise identical)")
    ap.add_argument("--hosts", type=int, default=1,
                    help=">= 2 forks that many CPU processes via "
                         "runtime.spawn_local, each decoding its owned pods "
                         "(or joins an existing runtime when REPRO_PROCESS_ID "
                         "is set by a cluster launcher)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address for --hosts "
                         ">= 2 under an external launcher (default: "
                         "REPRO_COORDINATOR env; spawn_local picks its own)")
    ap.add_argument("--rho", type=float, default=0.9, help="dme/drift correlation")
    ap.add_argument("--scheme", default="iid", choices=["iid", "band", "dirichlet"],
                    help="non-IID data partition for the §5 tasks")
    ap.add_argument("--alpha", type=float, default=0.3, help="dirichlet alpha")
    ap.add_argument("--seed", type=int, default=0,
                    help="round key + participation draw seed")
    ap.add_argument("--compare", action="store_true",
                    help="run the rand_k/rand_k_spatial/rand_proj_spatial family")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 3 rounds; CI entry-point guard")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto round timeline here "
                         "(one track per phase, byte/MSE annotations off the "
                         "exact ledger; open at https://ui.perfetto.dev — "
                         "docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-json", dest="metrics_json", default=None,
                    metavar="PATH",
                    help="write the metrics-registry snapshot + per-round "
                         "History records as JSON, one entry per compared "
                         "run (schema_version 2)")
    ap.add_argument("--profile-dir", dest="profile_dir", default=None,
                    metavar="DIR",
                    help="wrap the run in a jax.profiler trace (device-level "
                         "XLA view, complements --trace's system view)")
    return ap


def make_task(args):
    kw: dict = {"n_clients": args.clients, "seed": args.seed}
    if args.task in ("dme", "drift"):
        kw["rho"] = args.rho
        kw["d"] = 128 if args.smoke else 256
    elif args.task == "power_iteration":
        kw.update(d=256 if args.smoke else 1024,
                  samples=400 if args.smoke else 4000, scheme=args.scheme,
                  alpha=args.alpha)
    elif args.task == "kmeans":
        kw.update(d=64 if args.smoke else 256, samples=400 if args.smoke else 4000,
                  scheme=args.scheme, alpha=args.alpha)
    elif args.task == "linear_regression":
        kw.update(d=128 if args.smoke else 512, samples=400 if args.smoke else 4000,
                  scheme=args.scheme, alpha=args.alpha)
    elif args.task == "logistic_regression":
        kw.update(feat=32 if args.smoke else 64, samples=400 if args.smoke else 4000,
                  scheme=args.scheme, alpha=args.alpha)
    return get_task(args.task, **kw)


def run_one(task, args, name, est_kw, ctx=None):
    d_block = args.d_block or min(1024, max(64, 1 << (task.dim - 1).bit_length()))
    if getattr(args, "budget", "manual") == "auto":
        k = codec.suggest_budget(task.n_clients, getattr(args, "jl_eps", 0.5),
                                 d_block)
    else:
        k = args.k or max(1, d_block // 10)
    if getattr(args, "no_fused_kernels", False) and name == "rand_proj_spatial":
        est_kw = dict(est_kw, decode_method="gram")
    spec = codec.build(
        name, k=k, d_block=d_block,
        payload_dtype=getattr(args, "payload_dtype", "float32"),
        ef=getattr(args, "ef", False),
        temporal=getattr(args, "client_temporal", False),
        entropy_code=getattr(args, "entropy_code", False),
        **est_kw,
    )
    cohort = Cohort(n_clients=task.n_clients, participation=args.participation,
                    dropout=args.dropout)
    mesh = None
    if args.backend == "shard_map":
        # all local devices become the client axis (1 device on plain CPU)
        import jax

        mesh = jax.make_mesh((jax.device_count(),), ("pod",))
    cfg = rounds_lib.RoundConfig(
        n_rounds=3 if args.smoke else args.rounds, seed=args.seed,
        temporal=args.temporal, backend=args.backend, mesh=mesh,
        async_rounds=getattr(args, "async_rounds", False),
        staleness=getattr(args, "staleness", 1),
        stale_weight=getattr(args, "stale_weight", 1.0),
        overlap=getattr(args, "overlap", False),
        ownership=getattr(args, "ownership", False),
        n_owners=getattr(args, "owners", 0),
        hierarchy="hier" if getattr(args, "pods", 1) > 1 else "flat",
        pods=getattr(args, "pods", 1),
        runtime=ctx,
        adaptive_budgets=getattr(args, "adaptive_budgets", False),
    )
    state, hist = rounds_lib.run_rounds(task, spec, cohort, cfg)
    return spec, state, hist


def report(task, spec, hist, verbose=True):
    if verbose:
        cum = 0
        for t, (m, mse, b, ns, nst) in enumerate(
            zip(hist.metric, hist.mse, hist.bytes, hist.n_survivors,
                hist.n_stale)
        ):
            cum += b
            stale = f"  stale={nst}" if nst else ""
            print(f"  round {t:3d}  {task.metric_name}={m:.5f}  mse={mse:.6f}  "
                  f"survivors={ns}  bytes={cum}{stale}")
    mean_mse = float(np.nanmean(hist.mse))
    final = ("" if task.metric is None
             else f"final_{task.metric_name}={hist.metric[-1]:.5f}  ")
    coded = ("" if hist.total_coded_bytes == hist.total_bytes
             else f"  coded_bytes={hist.total_coded_bytes}")
    print(f"{task.name:20s} {spec.name}({spec.transform or '-'})  k={spec.k} "
          f"d_block={spec.d_block}  rounds={len(hist.mse)}  "
          f"{final}mean_mse={mean_mse:.6f}  total_bytes={hist.total_bytes}"
          f"{coded}")
    return mean_mse


def _nan_to_none(obj):
    """NaN -> null so the exported JSON stays strict-parser friendly."""
    if isinstance(obj, float) and math.isnan(obj):
        return None
    if isinstance(obj, dict):
        return {k: _nan_to_none(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_nan_to_none(v) for v in obj]
    return obj


def _run_meta(args, runs) -> dict:
    """Run metadata + ledger totals shared by the trace file and the metrics
    export — what tools/trace_report.py validates the trace events against.
    ``runs``: [(estimator label, History, metrics snapshot | None), ...]
    (several under --compare)."""
    import jax

    return {
        "task": args.task,
        "estimators": [label for label, _, _ in runs],
        "backend": args.backend,
        "pods": getattr(args, "pods", 1),
        "hosts": getattr(args, "hosts", 1),
        "seed": args.seed,
        "n_rounds": sum(len(h.mse) for _, h, _ in runs),
        "ledger_total_bytes": sum(h.total_bytes for _, h, _ in runs),
        "ledger_coded_bytes": sum(h.total_coded_bytes for _, h, _ in runs),
        "ledger_stale_bytes": sum(h.total_stale_bytes for _, h, _ in runs),
        "ledger_intra_pod_bytes": sum(h.total_intra_pod_bytes
                                      for _, h, _ in runs),
        "ledger_dcn_bytes": sum(h.total_dcn_bytes for _, h, _ in runs),
        "jax_version": jax.__version__,
        "jax_backend": jax.default_backend(),
    }


def _capture_metrics(args):
    """Per-run metrics snapshot for ``--metrics-json``: read the registry,
    then RESET it so the next compared run starts from zero — each run's
    export is its own counters, not a cumulative last-writer-wins blob.
    (Tracer events are untouched: the registry and the timeline are separate
    stores, and the trace metadata ledger sums all runs by design.)"""
    if not args.metrics_json:
        return None
    snap = obs.snapshot()
    obs.reset()
    return snap


def _write_obs_outputs(args, tracer, runs) -> None:
    if not runs or not (args.trace or args.metrics_json):
        return
    meta = _run_meta(args, runs)
    if tracer is not None:
        for mk, mv in meta.items():
            tracer.set_meta(mk, mv)
        tracer.write(args.trace)
        obs.uninstall_tracer()
        print(f"trace: {args.trace}  (open at https://ui.perfetto.dev)")
    if args.metrics_json:
        out = {
            "schema_version": 2,
            "run": meta,
            # one entry per compared run, each with ITS OWN metrics snapshot
            # and round records (schema v1 kept one cumulative snapshot and a
            # label-keyed dict that collided on repeated labels)
            "runs": [
                {"estimator": label, "metrics": snap or {},
                 "rounds": h.round_records()}
                for label, h, snap in runs
            ],
        }
        with open(args.metrics_json, "w") as f:
            json.dump(_nan_to_none(out), f, indent=1)
        print(f"metrics: {args.metrics_json}")


def _cli_worker(ctx, argv):
    """Spawned-process body of ``--hosts N``: re-enters main() with the env
    naming this process, so the child takes the join-existing-runtime path.
    Module-level because spawn children unpickle workers by qualified name.
    """
    return main(argv)


def main(argv=None) -> int:
    import os
    import sys

    args = build_parser().parse_args(argv)

    from ..runtime import launch as launch_lib

    if args.hosts > 1 and os.environ.get(launch_lib.ENV_PROCESS_ID) is None:
        # no launcher placed us: fork the processes ourselves (CI / laptop)
        from ..runtime import spawn_local

        child_argv = list(argv if argv is not None else sys.argv[1:])
        codes = spawn_local(_cli_worker, args.hosts, args=(child_argv,))
        return max(codes)

    ctx = None
    if args.hosts > 1 or os.environ.get(launch_lib.ENV_NUM_PROCESSES, "1") != "1":
        ctx = launch_lib.initialize(
            launch_lib.Topology.from_env(coordinator=args.coordinator)
        )
    primary = ctx is None or ctx.process_id == 0

    task = make_task(args)

    tracer = None
    if args.trace or args.metrics_json:
        obs.enable()
    if args.trace:
        tracer = obs.install_tracer(obs.Tracer())

    runs = []
    with obs.profiler_session(args.profile_dir):
        if args.compare:
            # under --trace the runs share one timeline: events accumulate
            # across estimators and the metadata ledger sums all of them
            results = {}
            for name, kw in COMPARE:
                spec, _, hist = run_one(task, args, name, kw, ctx=ctx)
                runs.append((name, hist, _capture_metrics(args)))
                mean_mse = float(np.nanmean(hist.mse))
                if primary:
                    report(task, spec, hist, verbose=False)
                results[f"{name}({kw.get('transform', '-')})"] = (
                    mean_mse, hist.total_bytes
                )
            if primary:
                print("\nMSE at equal bytes (same k, same round keys):")
                for label, (mse, b) in sorted(results.items(),
                                              key=lambda kv: kv[1][0]):
                    print(f"  {label:28s} mean_mse={mse:.6f}  bytes={b}")
        else:
            est_kw = {"transform": args.transform}
            spec, state, hist = run_one(task, args, args.estimator, est_kw,
                                        ctx=ctx)
            runs.append((args.estimator, hist, _capture_metrics(args)))
            if primary:
                report(task, spec, hist, verbose=not args.smoke)
                if "accuracy" in task.aux:
                    print(f"  final accuracy: "
                          f"{task.aux['accuracy'](state):.4f}")

    # every process holds the identical History; only one writes artifacts
    if primary:
        _write_obs_outputs(args, tracer, runs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
