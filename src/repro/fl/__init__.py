"""repro.fl — federated round orchestration on top of the DME estimators.

The paper positions Rand-Proj-Spatial as a subroutine for Federated Learning;
this package is the workload layer that runs it as one: a client population
model (partial participation, dropout, non-IID data, heterogeneous budgets),
a server with online correlation tracking and temporal side-information
decoding, a round driver with exact payload-byte accounting, and the paper's
§5 task library. See docs/DESIGN.md §8.
"""
from .clients import Cohort, Participation, partition  # noqa: F401
from .rounds import History, RoundConfig, run_rounds  # noqa: F401
from .server import ServerState, resolve_pipeline, resolve_spec  # noqa: F401
from .tasks import TASKS, Task, get_task  # noqa: F401
