"""Client-side population model for federated rounds.

A ``Cohort`` describes everything about the client population that is NOT the
task: how many clients exist, what fraction the server samples each round
(partial participation), how often a sampled client fails to report (dropout/
straggler), and each client's communication budget k_i (heterogeneous-budget
cohorts are decoded per budget group, docs/DESIGN.md §8.3).

Sampling is host-side numpy (deterministic in (seed, round)) because the set
of participants must be CONCRETE: payload stacks are shaped by who reports,
and the decode re-derives each survivor's randomness from its actual client
id (``client_ids`` in the codec pipeline).

Client-held cross-round state (error-feedback residuals, per-client temporal
memories) lives in a stacked ``codec.ClientState`` created by
``Cohort.init_state`` — one row per client, sliced/scattered by the round
driver as participation dictates.

Data partition helpers implement the two non-IID schemes used by the paper's
§5 tasks and by Jhunjhunwala et al. 2021: label-band (label-sorted contiguous
shards, paper App. D) and Dirichlet(alpha) class mixtures (the standard FL
heterogeneity knob).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Participation:
    """One round's sampling outcome: who was asked, who reported."""

    sampled: np.ndarray    # ids the server selected this round
    survivors: np.ndarray  # subset that actually reported (post dropout)

    @property
    def n_sampled(self) -> int:
        return len(self.sampled)

    @property
    def n_survivors(self) -> int:
        return len(self.survivors)

    @property
    def stragglers(self) -> np.ndarray:
        """Sampled clients that missed the round's reporting deadline.

        The sync driver drops them (their randomness never enters the
        decode); the async driver (``RoundConfig(async_rounds=True)``) treats
        them as LATE — they still encode this round's vectors, and their
        payloads are admitted into the next round's decode at staleness 1.
        """
        return np.setdiff1d(self.sampled, self.survivors)


@dataclasses.dataclass(frozen=True)
class Cohort:
    n_clients: int
    participation: float = 1.0          # fraction sampled per round
    dropout: float = 0.0                # P(sampled client fails to report)
    budgets: tuple[int, ...] | None = None  # per-client k_i; None => spec.k

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout must be in [0, 1)")
        if self.budgets is not None and len(self.budgets) != self.n_clients:
            raise ValueError("budgets must have one entry per client")

    def sample_round(self, seed: int, t: int) -> Participation:
        """Deterministic (seed, t) participation draw; >= 1 survivor always.

        Dropout keeps at least one reporter so a round is never empty — a
        fully-silent round would have no payloads to decode and the driver
        simply reuses the previous model state, which is equivalent.
        """
        rng = np.random.default_rng(np.random.SeedSequence([seed, t, 0xF1]))
        n_sampled = max(1, int(round(self.participation * self.n_clients)))
        sampled = np.sort(rng.choice(self.n_clients, n_sampled, replace=False))
        if self.dropout <= 0.0:
            return Participation(sampled=sampled, survivors=sampled)
        alive = rng.random(n_sampled) >= self.dropout
        if not alive.any():
            alive[rng.integers(n_sampled)] = True
        return Participation(sampled=sampled, survivors=sampled[alive])

    def init_state(self, pipe, n_chunks: int):
        """Stacked per-client ``codec.ClientState`` for this cohort (EF
        residual rows + temporal memories), or None for stateless pipelines.

        This is where client-held state lives in the simulation: row i IS
        client i's state, and doubles as the server's mirror (temporal memory
        updates are deterministic functions of transmitted payloads, so both
        sides agree — docs/DESIGN.md §8.2)."""
        from ..core.codec import as_pipeline

        return as_pipeline(pipe).init_client_state(self.n_clients, n_chunks)

    def budget_groups(self, ids: np.ndarray, default_k: int):
        """Group client ids by their budget k_i -> [(k, ids_with_that_k), ...].

        Correlation is exploited within a group (one joint decode per k); the
        group means are then combined weighted by group size, which is exactly
        the overall participants' mean in expectation.
        """
        if self.budgets is None:
            return [(default_k, np.asarray(ids))]
        ks = np.asarray([self.budgets[i] for i in ids])
        return [(int(k), np.asarray(ids)[ks == k]) for k in sorted(set(ks.tolist()))]


# ------------------------------------------------------------- data partition


def band_assignment(labels: np.ndarray, n_clients: int) -> list[np.ndarray]:
    """Label-sorted contiguous shards (paper App. D): client i gets the i-th
    band of the label-sorted sample order — maximal label skew."""
    order = np.argsort(labels, kind="stable")
    return [np.sort(s) for s in np.array_split(order, n_clients)]


def dirichlet_assignment(
    labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0
) -> list[np.ndarray]:
    """Dirichlet(alpha) non-IID split: each client draws a class mixture
    p_i ~ Dir(alpha) and samples (without replacement, balanced sizes) from
    the classes accordingly. Small alpha => near-single-class clients."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD1]))
    classes = np.unique(labels)
    by_class = {c: rng.permutation(np.flatnonzero(labels == c)) for c in classes}
    used = {c: 0 for c in classes}
    per_client = len(labels) // n_clients
    out = []
    for i in range(n_clients):
        mix = rng.dirichlet(np.full(len(classes), alpha))
        want = np.floor(mix * per_client).astype(int)
        want[rng.integers(len(classes))] += per_client - want.sum()
        take: list[np.ndarray] = []
        short = 0
        for c, w in zip(classes, want):
            pool = by_class[c]
            got = pool[used[c]: used[c] + w]
            used[c] += len(got)
            short += w - len(got)
            take.append(got)
        # backfill exhausted classes from whatever remains, round-robin
        while short > 0:
            for c in classes:
                if short == 0:
                    break
                pool = by_class[c]
                if used[c] < len(pool):
                    take.append(pool[used[c]: used[c] + 1])
                    used[c] += 1
                    short -= 1
        out.append(np.sort(np.concatenate(take)))
    return out


def partition(
    x: np.ndarray,
    labels: np.ndarray,
    n_clients: int,
    scheme: str = "iid",
    alpha: float = 0.3,
    seed: int = 0,
) -> np.ndarray:
    """Shard samples into (n_clients, m, ...) by the named scheme.

    Shards are trimmed to the minimum per-client count so the result stacks.
    """
    if scheme == "iid":
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0x11D]))
        order = rng.permutation(len(x))
        shards = np.array_split(order, n_clients)
    elif scheme == "band":
        shards = band_assignment(labels, n_clients)
    elif scheme == "dirichlet":
        shards = dirichlet_assignment(labels, n_clients, alpha, seed)
    else:
        raise ValueError(f"unknown partition scheme {scheme!r}")
    m = min(len(s) for s in shards)
    return np.stack([x[s[:m]] for s in shards])
