"""Paper §5 task library: each task is a pure ``(state, mean) -> state`` step.

A ``Task`` cleanly separates the three things a federated round needs:

    client_vectors(state, key) -> (n, dim)   what each client WOULD send
    step(state, mean)          -> state      how the server's model advances
    metric(state)              -> float      task-level error (lower = better)

so any task composes with any estimator, any cohort, and either decode mode
(spatial / temporal) — the round driver (fl.rounds) owns everything between
"clients computed vectors" and "server obtained a mean".

Tasks
-----
- ``power_iteration``   distributed power iteration (paper Fig. 4 top)
- ``kmeans``            distributed k-means centroid averaging
- ``linear_regression`` distributed GD on least squares
- ``logistic_regression`` softmax regression on gaussian class blobs
- ``dme``               pure one-shot mean estimation, correlation rho dialed
                        in exactly (x_i = u + sigma * eps_i with
                        sigma^2 = 1/rho - 1 => E[R] ~= rho (n-1))
- ``drift``             slowly-rotating common component: the temporal
                        decoder's showcase (x_i(t) = u(t) + noise, u drifts
                        by ~omega per round)

Datasets are offline synthetic stand-ins with the paper's shapes (image-like
low-rank + class structure); non-IID splits use fl.clients.partition
("band" = label-sorted shards per paper App. D, "dirichlet" = Dir(alpha)
mixtures).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import clients as clients_lib


@dataclasses.dataclass(frozen=True)
class Task:
    name: str
    n_clients: int
    dim: int
    init: Callable[[Any], dict]                 # key -> state
    client_vectors: Callable[[dict, Any], jnp.ndarray]  # (state, key) -> (n, dim)
    step: Callable[[dict, jnp.ndarray], dict]   # (state, mean) -> state
    metric: Callable[[dict], float] | None = None
    metric_name: str = "err"
    aux: dict = dataclasses.field(default_factory=dict)


def _image_like_data(n_samples, d, seed=0, n_classes=10):
    """Low-rank + class-structured features (Fashion-MNIST-like moments)."""
    rng = np.random.default_rng(seed)
    rank = 16
    basis = rng.standard_normal((rank, d)) * (1.0 / np.sqrt(d))
    scale = np.geomspace(3.0, 0.3, rank)[:, None]
    z = rng.standard_normal((n_samples, rank))
    labels = rng.integers(0, n_classes, n_samples)
    cls_shift = rng.standard_normal((n_classes, d)) * 0.4 / np.sqrt(d)
    x = z @ (basis * scale) + cls_shift[labels]
    x = x + rng.standard_normal((n_samples, d)) * 0.05
    return x.astype(np.float32), labels


def power_iteration(
    n_clients=10, d=1024, samples=4000, scheme="iid", alpha=0.3, seed=0
) -> Task:
    x, labels = _image_like_data(samples, d, seed=seed)
    shards = jnp.asarray(
        clients_lib.partition(x, labels, n_clients, scheme, alpha, seed)
    )  # (n, m, d)
    v_top = np.linalg.eigh(x.T @ x / len(x))[1][:, -1]

    def init(key):
        return {"t": 0, "v": jnp.ones(d) / jnp.sqrt(d)}

    @jax.jit
    def client_vectors(state, key):
        local = jnp.einsum("nmd,d->nm", shards, state["v"])
        vi = jnp.einsum("nmd,nm->nd", shards, local)
        return vi / (jnp.linalg.norm(vi, axis=1, keepdims=True) + 1e-9)

    def step(state, mean):
        v = mean / (jnp.linalg.norm(mean) + 1e-9)
        return {"t": state["t"] + 1, "v": v}

    def metric(state):
        v = np.asarray(state["v"])
        return float(min(np.linalg.norm(v - v_top), np.linalg.norm(v + v_top)))

    return Task(
        name="power_iteration", n_clients=n_clients, dim=d, init=init,
        client_vectors=client_vectors, step=step, metric=metric,
        metric_name="eig_err", aux={"v_top": v_top, "shards": shards},
    )


def kmeans(
    n_clients=10, d=256, samples=4000, n_clusters=10, scheme="iid", alpha=0.3,
    seed=2,
) -> Task:
    x, labels = _image_like_data(samples, d, seed=seed, n_classes=n_clusters)
    shards = jnp.asarray(
        clients_lib.partition(x, labels, n_clients, scheme, alpha, seed)
    )
    x_all = jnp.asarray(x)
    init_cents = jnp.asarray(x[:: samples // n_clusters][:n_clusters])

    def init(key):
        return {"t": 0, "cents": init_cents}

    @jax.jit
    def client_vectors(state, key):
        cents = state["cents"]
        d2 = ((shards[:, :, None, :] - cents[None, None]) ** 2).sum(-1)
        oh = jax.nn.one_hot(jnp.argmin(d2, -1), n_clusters, dtype=jnp.float32)
        sums = jnp.einsum("nmc,nmd->ncd", oh, shards)
        cnts = jnp.maximum(oh.sum(1)[..., None], 1.0)
        local = jnp.where(oh.sum(1)[..., None] > 0, sums / cnts, cents[None])
        return local.reshape(n_clients, n_clusters * d)

    def step(state, mean):
        return {"t": state["t"] + 1, "cents": mean.reshape(n_clusters, d)}

    @jax.jit
    def _loss(cents):
        d2 = ((x_all[:, None, :] - cents[None]) ** 2).sum(-1)
        return d2.min(-1).mean()

    def metric(state):
        return float(_loss(state["cents"]))

    return Task(
        name="kmeans", n_clients=n_clients, dim=n_clusters * d, init=init,
        client_vectors=client_vectors, step=step, metric=metric,
        metric_name="quant_loss", aux={"shards": shards},
    )


def linear_regression(
    n_clients=10, d=512, samples=4000, lr=0.05, scheme="iid", alpha=0.3, seed=3
) -> Task:
    rng = np.random.default_rng(seed)
    w_star = rng.standard_normal(d).astype(np.float32) / np.sqrt(d)
    x, labels = _image_like_data(samples, d, seed=seed + 1)
    y = x @ w_star + rng.standard_normal(samples).astype(np.float32) * 0.01
    order_key = y if scheme == "band" else labels  # band-sort by target value
    xs = jnp.asarray(clients_lib.partition(x, order_key, n_clients, scheme, alpha, seed))
    ys = jnp.asarray(clients_lib.partition(y, order_key, n_clients, scheme, alpha, seed))

    def init(key):
        return {"t": 0, "w": jnp.zeros(d)}

    @jax.jit
    def client_vectors(state, key):
        pred = jnp.einsum("nmd,d->nm", xs, state["w"])
        return 2 * jnp.einsum("nmd,nm->nd", xs, pred - ys) / xs.shape[1]

    def step(state, mean):
        return {"t": state["t"] + 1, "w": state["w"] - lr * mean}

    @jax.jit
    def _loss(w):
        pred = jnp.einsum("nmd,d->nm", xs, w)
        return ((pred - ys) ** 2).mean()

    def metric(state):
        return float(_loss(state["w"]))

    return Task(
        name="linear_regression", n_clients=n_clients, dim=d, init=init,
        client_vectors=client_vectors, step=step, metric=metric,
        metric_name="mse_loss", aux={"w_star": w_star},
    )


def logistic_regression(
    n_clients=10, feat=64, n_classes=10, samples=4000, lr=0.5,
    scheme="dirichlet", alpha=0.3, seed=5,
) -> Task:
    """Softmax regression on gaussian class blobs; Dirichlet non-IID default."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_classes, feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, samples)
    x = centers[labels] + 0.8 * rng.standard_normal((samples, feat)).astype(np.float32)
    xs = jnp.asarray(clients_lib.partition(x, labels, n_clients, scheme, alpha, seed))
    ys = jnp.asarray(clients_lib.partition(labels, labels, n_clients, scheme, alpha, seed))
    x_all, y_all = jnp.asarray(x), jnp.asarray(labels)
    dim = n_classes * feat

    def _grads(w_flat, xb, yb):
        w = w_flat.reshape(n_classes, feat)
        logits = xb @ w.T
        p = jax.nn.softmax(logits, axis=-1)
        oh = jax.nn.one_hot(yb, n_classes, dtype=jnp.float32)
        return ((p - oh).T @ xb / xb.shape[0]).reshape(-1)

    def init(key):
        return {"t": 0, "w": jnp.zeros(dim)}

    @jax.jit
    def client_vectors(state, key):
        return jax.vmap(lambda xb, yb: _grads(state["w"], xb, yb))(xs, ys)

    def step(state, mean):
        return {"t": state["t"] + 1, "w": state["w"] - lr * mean}

    @jax.jit
    def _eval(w_flat):
        logits = x_all @ w_flat.reshape(n_classes, feat).T
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y_all[:, None], axis=-1).mean()
        acc = (jnp.argmax(logits, -1) == y_all).mean()
        return nll, acc

    def metric(state):
        return float(_eval(state["w"])[0])

    def accuracy(state):
        return float(_eval(state["w"])[1])

    return Task(
        name="logistic_regression", n_clients=n_clients, dim=dim, init=init,
        client_vectors=client_vectors, step=step, metric=metric,
        metric_name="xent", aux={"accuracy": accuracy},
    )


def dme(n_clients=8, d=256, rho=0.9, seed=0) -> Task:
    """Static correlated mean estimation: E[R] ~= rho * (n - 1).

    x_i = u + sigma eps_i with ||u|| = 1, eps_i ~ N(0, I/d), and
    sigma = sqrt(1/rho - 1):  R = n<u,u>/(<u,u> + sigma^2) ... = rho (n-1).
    client_vectors is constant across rounds, so averaging the per-round MSE
    over many rounds Monte-Carlo-averages over the estimator's randomness —
    this is the harness' Fig. 3/4-style MSE-at-equal-bytes probe.
    """
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(d)
    u /= np.linalg.norm(u)
    sigma = np.sqrt(1.0 / rho - 1.0) if rho > 0 else 10.0
    eps = rng.standard_normal((n_clients, d)) / np.sqrt(d)
    xs = jnp.asarray(u[None] + sigma * eps, jnp.float32)

    def init(key):
        return {"t": 0, "mean": jnp.zeros(d)}

    def client_vectors(state, key):
        return xs

    def step(state, mean):
        return {"t": state["t"] + 1, "mean": mean}

    return Task(
        name="dme", n_clients=n_clients, dim=d, init=init,
        client_vectors=client_vectors, step=step, metric=None,
        metric_name="mse", aux={"xs": xs, "rho": rho},
    )


def drift(n_clients=8, d=256, rho=0.95, omega=0.03, client_bias=0.0,
          seed=0) -> Task:
    """Slowly-drifting common component: u(t) rotates by ~omega rad/round.

    Per-round ||u(t) - u(t-1)|| ~= omega << 1 = ||u(t)||, so a temporal
    decoder that encodes deltas against the server's previous estimate spends
    its k on a vector ~1/omega times smaller — the Rand-k-Temporal argument.
    Fresh per-round client noise keeps the task honest (the delta is never 0).

    ``client_bias`` > 0 adds a PERSISTENT per-client offset b_i (unit vector
    scaled by client_bias): x_i(t) = u(t) + b_i + sigma eps_i(t). Broadcast
    temporal decoding cannot capture b_i (the server's estimate carries only
    mean(b)); per-client temporal memories can — this is the workload where
    true Rand-k-Temporal separates from the broadcast variant.
    """
    rng = np.random.default_rng(seed)
    u0 = rng.standard_normal(d)
    u0 /= np.linalg.norm(u0)
    u1 = rng.standard_normal(d)
    u1 -= u0 * (u0 @ u1)
    u1 /= np.linalg.norm(u1)
    u0_j, u1_j = jnp.asarray(u0, jnp.float32), jnp.asarray(u1, jnp.float32)
    sigma = float(np.sqrt(1.0 / rho - 1.0)) if rho > 0 else 10.0
    b = rng.standard_normal((n_clients, d))
    b = client_bias * b / np.linalg.norm(b, axis=1, keepdims=True)
    b_j = jnp.asarray(b, jnp.float32)

    def init(key):
        return {"t": 0, "mean": jnp.zeros(d)}

    def client_vectors(state, key):
        t = state["t"]
        u_t = jnp.cos(omega * t) * u0_j + jnp.sin(omega * t) * u1_j
        eps = jax.random.normal(key, (n_clients, d)) / jnp.sqrt(d)
        return u_t[None] + b_j + sigma * eps

    def step(state, mean):
        return {"t": state["t"] + 1, "mean": mean}

    return Task(
        name="drift", n_clients=n_clients, dim=d, init=init,
        client_vectors=client_vectors, step=step, metric=None,
        metric_name="mse", aux={"rho": rho, "omega": omega,
                                "client_bias": client_bias},
    )


TASKS: dict[str, Callable[..., Task]] = {
    "power_iteration": power_iteration,
    "kmeans": kmeans,
    "linear_regression": linear_regression,
    "logistic_regression": logistic_regression,
    "dme": dme,
    "drift": drift,
}


def get_task(name: str, **kw) -> Task:
    if name not in TASKS:
        raise KeyError(f"unknown task {name!r}; have {sorted(TASKS)}")
    return TASKS[name](**kw)
