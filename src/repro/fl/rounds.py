"""The federated round driver: encode -> ledger -> decode -> task step.

One ``run_rounds`` call plays ``n_rounds`` of

    1. clients compute their round vectors          (task.client_vectors)
    2. the server samples participants; some drop   (cohort.sample_round)
    3. survivors chunk + encode through the codec pipeline — optionally
       against side information: the server's previous estimate (broadcast
       temporal) or each client's own memory in ClientState (true per-client
       Rand-k-Temporal)                             (core.codec)
    4. every transmitted payload byte is ledgered straight off the payload's
       self-described schema                        (Konecny & Richtarik-style
       accuracy-vs-communication accounting); with a ``code`` stage the
       parallel ``History.coded_bytes`` column ledgers the EXACT
       entropy-coded stream length of the same traffic
    5. the server decodes the survivors' mean — renormalising by who actually
       reported, with their actual client ids, per budget group
    6. the server updates its correlation tracker and temporal state
    7. the task advances                            (task.step)

``spec`` may be a ``codec.Pipeline`` or a bare sparsifier config.
Heterogeneous budgets and error feedback
compose on EVERY backend now: budget groups are decoded independently (the
group's budget rides in each payload's meta), EF residual rows live per
client in ``ClientState.ef`` and follow their own k_i.

Backends: "local" drives the pipeline directly (CPU-friendly; the only
backend for per-client temporal memories, which need the driver to mirror
each client's state); "gspmd" and "shard_map" route steps 3-5 through
repro.dist.collectives on a mesh — the same math, with payload-sized
cross-device traffic on the shard_map path.

Async rounds (``RoundConfig(async_rounds=True)``, docs/DESIGN.md §9): the
server decodes whoever reported by the deadline and moves on — stragglers
are not waited for. Their encodes (of THIS round's vectors, overlapping the
server's decode) complete late; the payloads are buffered and admitted into
the NEXT round's decode at staleness 1 instead of being dropped: the stale
group is decoded with its own round key and side information (temporal
machinery is exactly what makes a stale payload usable), tagged
``payload.meta.staleness = 1``, ledgered at arrival, and combined with the
fresh survivors' mean re-weighted by client count (``cfg.stale_weight`` per
stale client). With ``dropout=0`` the async driver is bit-identical to the
sync one — the buffer never fills.

Overlapped decode (``RoundConfig(overlap=True)``): steps 3-5 stream the
chunk axis through ``dist.collectives``'s double buffer (encode of chunk
c+1 while chunk c's payload is in flight), bit-identical to the synchronous
path on every backend; requires a stateless, chunk-streamable pipeline.

Sharded server decode (``RoundConfig(ownership=True)``, docs/DESIGN.md §10):
step 5 runs owner-partitioned — each owner shard decodes only the chunk
slice it owns (payloads routed by an ``all_to_all`` on the shard_map
backend; the same slices/offsets iterated in-process on local/gspmd), and
``History.intra_pod_bytes`` ledgers the modelled server-side receive
traffic, which the ownership route strictly reduces at n_owners >= 2
whenever remote payload bytes exceed the decoded vector's d bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import chunking, correlation
from ..core.codec import (
    ClientState,
    adaptive_chunk_budgets,
    as_pipeline,
    coded_payload_nbytes,
    with_staleness,
)
from ..dist import collectives
from . import server as server_lib
from .clients import Cohort, Participation
from .tasks import Task


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    n_rounds: int = 20
    seed: int = 0
    temporal: bool = False      # broadcast temporal: decode deltas vs prev estimate
    track_r: bool | None = None  # default: only for transform="wavg"
    r_gamma: float = 0.3
    backend: str = "local"      # local | gspmd | shard_map
    mesh: Any = None            # required for gspmd / shard_map
    client_axes: tuple = ("pod",)
    async_rounds: bool = False  # staleness-1 buffered aggregation (§9)
    staleness: int = 1          # max admitted payload age; 0 = drop late payloads
    stale_weight: float = 1.0   # per-client weight of an admitted stale payload
    overlap: bool = False       # double-buffered chunk streaming in the decode
    overlap_tile: int = 1       # chunks per stream tile
    ownership: bool = False     # sharded server decode (chunk ownership, §10)
    # per-chunk adaptive budgets (docs/DESIGN.md §3.8): rewrite each round's
    # chunk budget vector from the previous estimate's per-chunk norm mass
    # (rand_k only, local backend, flat hierarchy, sync rounds)
    adaptive_budgets: bool = False
    # logical owner shards on local/gspmd (0 = derive from the mesh); the
    # shard_map backend always uses the mesh client-axes extent (the
    # all_to_all routing must match the physical shards)
    n_owners: int = 0
    # hierarchical (per-pod) aggregation, docs/DESIGN.md §11: "hier" decodes
    # pod-local (each pod's server sees only its cohort, carries its own
    # online R estimate) then combines d-sized estimates across pods.
    # Bitwise identical to "flat" at pods=1.
    hierarchy: str = "flat"     # flat | hier
    pods: int = 1               # pod count under hierarchy="hier"
    # runtime.RuntimeContext for multi-process execution (None = all pods
    # decoded in this process; ignored under hierarchy="flat")
    runtime: Any = None


@dataclasses.dataclass
class _StaleBuffer:
    """Round t's straggler encodes, waiting for admission at round t+1.

    The simulation stores the encode INPUTS (round key, chunk rows, side
    information / temporal-memory snapshot) rather than the arrays that
    crossed the wire: encode is deterministic in them, so the admitted
    payload is re-derived bit-exactly at decode time — the same trick the
    decode itself uses to re-derive survivor randomness from client ids.
    """

    key: Any            # the round key the stragglers encoded with
    ids: np.ndarray     # straggler client ids
    xs_rows: Any        # (m, C, d_block) their round-t chunk rows
    side: Any           # broadcast side info they encoded against (or None)
    mem_rows: Any       # per-client temporal memory snapshot rows (or None)


@dataclasses.dataclass
class History:
    """Per-round trajectory + ledger. Lists are length n_rounds."""

    metric: list = dataclasses.field(default_factory=list)
    mse: list = dataclasses.field(default_factory=list)      # vs survivors' true mean
    mse_pop: list = dataclasses.field(default_factory=list)  # vs ALL clients' mean
    bytes: list = dataclasses.field(default_factory=list)    # transmitted this round
    # EXACT entropy-coded wire bytes of the same traffic: equal to ``bytes``
    # when the pipeline carries no code stage; with codec.EntropyCode it is
    # the summed length of every client's coded stream (stale arrivals are
    # ledgered at raw size — the straggler's coded length belongs to ITS
    # encode round, which already buffered the inputs, not the re-derivation)
    coded_bytes: list = dataclasses.field(default_factory=list)
    n_survivors: list = dataclasses.field(default_factory=list)
    n_sampled: list = dataclasses.field(default_factory=list)
    n_stale: list = dataclasses.field(default_factory=list)  # late payloads admitted
    # late-ARRIVAL bytes (subset of ``bytes``): every late payload that lands
    # is ledgered, admitted into the decode or superseded by a fresh report
    stale_bytes: list = dataclasses.field(default_factory=list)
    # modelled server-side receive traffic of the round's decode, summed over
    # shards (dist.collectives.intra_pod_traffic): the column the sharded
    # decode (RoundConfig.ownership) must strictly reduce at n_shards >= 2
    intra_pod_bytes: list = dataclasses.field(default_factory=list)
    # modelled cross-pod (DCN-tier) traffic of the hierarchical route
    # (runtime.comms.cross_pod_traffic); all zeros under hierarchy="flat"
    # or pods=1 — nothing crosses a pod boundary
    dcn_bytes: list = dataclasses.field(default_factory=list)
    rho_hat: list = dataclasses.field(default_factory=list)  # tracker output (or nan)
    client_state: Any = None  # final stacked ClientState (None if stateless)

    @property
    def total_bytes(self) -> int:
        return int(np.sum(self.bytes))

    @property
    def total_coded_bytes(self) -> int:
        return int(np.sum(self.coded_bytes)) if self.coded_bytes else 0

    @property
    def total_intra_pod_bytes(self) -> int:
        return int(np.sum(self.intra_pod_bytes)) if self.intra_pod_bytes else 0

    @property
    def total_dcn_bytes(self) -> int:
        return int(np.sum(self.dcn_bytes)) if self.dcn_bytes else 0

    @property
    def total_stale_bytes(self) -> int:
        return int(np.sum(self.stale_bytes)) if self.stale_bytes else 0

    def bytes_to_target(self, target: float, key: str = "metric",
                        bytes_key: str = "bytes") -> int | None:
        """Cumulative bytes when the metric first reaches <= target.

        ``bytes_key="coded_bytes"`` accumulates the entropy-coded ledger
        instead of the raw schema bytes."""
        vals, cum = getattr(self, key), np.cumsum(getattr(self, bytes_key))
        for v, b in zip(vals, cum):
            if v is not None and not np.isnan(v) and v <= target:
                return int(b)
        return None

    _RECORD_KEYS = ("metric", "mse", "mse_pop", "bytes", "coded_bytes",
                    "n_survivors", "n_sampled", "n_stale", "stale_bytes",
                    "intra_pod_bytes", "dcn_bytes", "rho_hat")

    def round_records(self) -> list:
        """The trajectory as one dict per round (the ``--metrics-json``
        export): every parallel History list keyed by name, plus the round
        index — a flat schema consumers can load without knowing the
        dataclass layout."""
        return [
            {"round": t, **{k: getattr(self, k)[t] for k in self._RECORD_KEYS}}
            for t in range(len(self.mse))
        ]


def _should_track(pipe, cfg) -> bool:
    return cfg.track_r if cfg.track_r is not None else pipe.transform == "wavg"


def _scatter_rows(full, rows, ids_j):
    """Scatter updated per-client rows (a ClientState slice) back into the
    full stacked state; None subtrees pass through."""
    return jax.tree.map(lambda f, r: f.at[ids_j].set(r), full, rows)


def _group_local(pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate,
                 overlap=False, overlap_tile=1, plan=None):
    """One budget group on the local backend. Returns (group mean, updated
    full ClientState, stacked payloads for the tracker — None on the
    overlapped path, which never materialises the whole payload stack).

    ``plan`` (ChunkOwnership): run the server decode owner-partitioned —
    the same slices/offsets as the shard_map ownership route, so the local
    backend exercises (and bit-matches) the sharded decode."""
    ids_j = jnp.asarray(ids_g)
    # guard: payload_nbytes builds a PayloadMeta (disabled path stays free)
    group_bytes = (
        pipe_g.payload_nbytes(xs_chunks.shape[1]) * len(ids_g)
        if obs.enabled() else 0
    )
    if overlap:
        # stateless by construction (run_rounds validates): stream the chunk
        # axis through the dist layer's double buffer — bit-identical.
        # Encode and decode interleave tile-by-tile inside streamed_mean, so
        # the timeline gets ONE owner_decode span for the whole stream and a
        # zero-duration client_encode marker carrying the group's ledger bytes
        # (the byte invariant cares about attribution, not tile timing).
        obs.marker("fl", "client_encode", track="client_encode",
                   bytes=group_bytes, clients=len(ids_g), overlap=True)
        _mark_quantize(pipe_g)
        with obs.span("fl", "owner_decode", track="owner_decode",
                      clients=len(ids_g), overlap=True):
            dec, _ = collectives.streamed_mean(
                pipe_g, key, xs_chunks[ids_g], len(ids_g), client_ids=ids_j,
                side_info=side, tile=overlap_tile, ownership=plan,
            )
        return dec, cstate, None
    st_g = None
    if cstate is not None:
        st_g = jax.tree.map(lambda a: a[ids_j], cstate)
    with obs.span("fl", "client_encode", track="client_encode",
                  bytes=group_bytes, clients=len(ids_g), k=pipe_g.k):
        payloads, st_new = pipe_g.encode_all(
            key, xs_chunks[ids_g], client_ids=ids_j, side_info=side, states=st_g
        )
    _mark_quantize(pipe_g)
    if st_new is not None:
        cstate = _scatter_rows(cstate, st_new, ids_j)
    dec_side = side
    if mem_snapshot is not None:
        # per-client temporal: the server adds back the SURVIVORS' mean
        # memory (its mirror of the clients' side information)
        dec_side = jnp.mean(mem_snapshot[ids_j], axis=0)
    with obs.span("fl", "owner_decode", track="owner_decode",
                  clients=len(ids_g), sharded=plan is not None):
        if plan is not None:
            dec = collectives.sharded_decode(
                pipe_g, key, payloads, len(ids_g), plan, client_ids=ids_j
            )
            if dec_side is not None:
                dec = dec + dec_side
        else:
            dec = pipe_g.decode(
                key, payloads, len(ids_g), client_ids=ids_j, side_info=dec_side
            )
    return dec, cstate, payloads


def _mark_quantize(pipe_g):
    """Attribution marker for the quantize stage: its walltime is fused into
    the client encode (one vmapped program), so the timeline names the stage
    with a zero-duration event instead of claiming a separate duration."""
    if obs.enabled():
        q = pipe_g.quantizer
        obs.marker("fl", "quantize", track="quantize",
                   stage="none" if q is None else q.name)


def _ownership_arg(cfg):
    """The ``ownership=`` value forwarded to dist.collectives. On shard_map
    the MESH defines the owners (the all_to_all routing must match the
    physical shards, so ``n_owners`` is ignored there); on local/gspmd an
    explicit ``n_owners`` sets the logical owner count, else the plan derives
    from the mesh client axes."""
    if not cfg.ownership:
        return None
    if cfg.backend == "shard_map":
        return True
    return cfg.n_owners if cfg.n_owners else True


def _group_dist(pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate, cfg):
    """One budget group through dist.collectives (gspmd / shard_map).

    Per-client temporal memories compose here the same way the stale decode
    composes everywhere: the collectives move DELTAS (each client's chunk
    rows minus its own memory row — the exact subtraction the local encode
    performs), and the server mirrors the deterministic ClientState updates
    by re-running ``encode_all`` on its side (same key / ids / side /
    residual => identical payloads => identical memory and EF updates — the
    ``_measure_rho_dist`` re-derivation argument). The collective's own
    ``ef_next`` is ignored in that case: the mirror computes both new
    buffers in one pass.

    Returns (group mean, updated state, bytes, intra-pod bytes, delta)."""
    if mem_snapshot is not None:
        delta = xs_chunks - mem_snapshot  # per-client side info, row-wise
    elif side is not None:
        delta = xs_chunks - side[None]
    else:
        delta = xs_chunks
    tree = {"x": delta}
    ef_arr = cstate.ef if (cstate is not None and pipe_g.has_ef) else None
    if cfg.backend == "shard_map":
        if cfg.mesh is None:
            raise ValueError("backend='shard_map' needs cfg.mesh")
        mean_tree, info, ef_next = collectives.compressed_mean_tree_shardmap(
            pipe_g, key, tree, cfg.mesh, client_axes=cfg.client_axes,
            participants=ids_g, ef_chunks=ef_arr,
            overlap=cfg.overlap, overlap_tile=cfg.overlap_tile,
            ownership=_ownership_arg(cfg),
        )
    else:
        shardings = collectives.dme_shardings(cfg.mesh, cfg.client_axes)
        mean_tree, info, ef_next = collectives.compressed_mean_tree(
            pipe_g, key, tree, shardings, participants=ids_g, ef_chunks=ef_arr,
            overlap=cfg.overlap, overlap_tile=cfg.overlap_tile,
            ownership=_ownership_arg(cfg),
        )
    if mem_snapshot is not None:
        # mirror the clients' deterministic state transition server-side
        # (memory AND ef rows advance together inside encode_all)
        ids_j = jnp.asarray(ids_g)
        st_g = jax.tree.map(lambda a: a[ids_j], cstate)
        _, st_new = pipe_g.encode_all(
            key, xs_chunks[ids_g], client_ids=ids_j, states=st_g
        )
        cstate = _scatter_rows(cstate, st_new, ids_j)
    elif ef_next is not None:
        cstate = ClientState(ef=ef_next, memory=cstate.memory)
    mean_g = mean_tree["x"]
    if mem_snapshot is not None:
        mean_g = mean_g + jnp.mean(mem_snapshot[jnp.asarray(ids_g)], axis=0)
    elif side is not None:
        mean_g = mean_g + side
    # the dist paths encode+route+decode inside one collectives call (and on
    # shard_map inside one traced program), so the phases get attribution
    # markers here — bytes off the collectives' exact ledger; walltime spans
    # for the eager GSPMD path live in dist.collectives itself
    obs.marker("fl", "client_encode", track="client_encode",
               bytes=info["bytes_sent"], clients=len(ids_g),
               backend=cfg.backend)
    _mark_quantize(pipe_g)
    obs.marker("fl", "owner_decode", track="owner_decode",
               clients=len(ids_g), backend=cfg.backend)
    return mean_g, cstate, info["bytes_sent"], info["intra_pod_bytes"], delta


def _rederive_payloads(pipe_g, key, delta, ids_g, cstate):
    """Re-derive the group's transmitted payloads server-side (same key / ids
    / side / residual => identical payloads — encode is deterministic in
    them). Costs one extra encode of the group's survivors, payload-sized.
    Used where the payload stack never materialised: the collectives paths,
    the overlapped local path, and the coded-bytes ledger."""
    ids_j = jnp.asarray(ids_g)
    enc_in = delta[ids_g]
    if pipe_g.has_ef and cstate is not None and cstate.ef is not None:
        # ``cstate`` is the PRE-update state (the residual the clients added
        # before encoding), so the re-derived payloads match what was sent.
        enc_in = enc_in + cstate.ef[ids_j]
    payloads, _ = pipe_g.encode_all(key, enc_in, client_ids=ids_j)
    return payloads


def _measure_rho_dist(pipe_g, key, delta, ids_g, cstate):
    payloads = _rederive_payloads(pipe_g, key, delta, ids_g, cstate)
    return server_lib.measure_rho(pipe_g, key, payloads, ids_g)


def _side_and_memory(pipe, cfg, state_srv, cstate):
    """Round-start snapshot of the side information the clients encode
    against: (broadcast side info | None, per-client memory snapshot | None).
    Taken BEFORE any state row updates so straggler encodes (async mode) see
    exactly what an on-time encode would have."""
    if pipe.has_client_temporal:
        return None, cstate.memory
    if cfg.temporal or (pipe.temporal_stage is not None):
        return server_lib.side_info_for(state_srv, temporal=True), None
    return None, None


def _decode_round(pipe, key, xs_chunks, part, cohort, state_srv, cfg, cstate,
                  side, mem_snapshot):
    """Budget-grouped encode/decode over the survivors on any backend.

    Returns (mean_chunks, bytes_sent, coded_sent, intra_pod, rho_round,
    cstate). ``coded_sent`` is the exact entropy-coded wire ledger of the
    same payloads — equal to ``bytes_sent`` when the pipeline carries no
    code stage; otherwise the summed per-client coded stream lengths
    (re-derived server-side where the payload stack never materialised)."""
    groups = cohort.budget_groups(part.survivors, pipe.k)
    track = _should_track(pipe, cfg)
    n_eff = part.n_survivors
    n_chunks = xs_chunks.shape[1]
    plan = None
    if cfg.ownership and cfg.backend == "local":
        plan = collectives.ownership_plan(
            _ownership_arg(cfg), n_chunks, max(1, cfg.n_owners)
        )
    # per-chunk adaptive budgets: the previous estimate's per-chunk norm mass
    # sets this round's budget vector (round 0 has no estimate => uniform,
    # i.e. chunk_budgets stays unset)
    chunk_mass = None
    if cfg.adaptive_budgets and state_srv.prev_mean is not None:
        chunk_mass = np.asarray(
            jnp.sum(jnp.square(state_srv.prev_mean), axis=-1)
        )

    mean_chunks, bytes_sent, coded_sent, intra_pod, rho_parts = (
        None, 0, 0, 0, [])
    for k_g, ids_g in groups:
        if len(ids_g) == 0:
            continue
        pre_state = cstate
        pipe_g = server_lib.resolve_pipeline(
            pipe.with_budget(k_g), state_srv, len(ids_g)
        )
        if chunk_mass is not None:
            pipe_g = pipe_g.replace_sparsifier(
                chunk_budgets=adaptive_chunk_budgets(
                    chunk_mass, k_g, pipe.d_block)
            )
        if cfg.backend == "local":
            dec, cstate, payloads = _group_local(
                pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate,
                overlap=cfg.overlap, overlap_tile=cfg.overlap_tile, plan=plan,
            )
            raw_g = pipe_g.payload_nbytes(n_chunks) * len(ids_g)
            bytes_sent += raw_g
            intra_pod += collectives.intra_pod_traffic(
                pipe_g, len(ids_g), n_chunks,
                plan.n_shards if plan is not None else 1, plan=plan,
            )["intra_pod_bytes"]
            delta = None
            if payloads is None and (track or pipe_g.code_stage is not None):
                # overlapped path: payloads stayed tile-local; re-derive
                delta = xs_chunks if side is None else xs_chunks - side[None]
            if pipe_g.code_stage is None:
                coded_sent += raw_g
            else:
                pl = payloads if payloads is not None else _rederive_payloads(
                    pipe_g, key, delta, ids_g, pre_state)
                coded_sent += coded_payload_nbytes(pipe_g, pl)
            if not track:
                rho_g = None
            elif payloads is not None:
                rho_g = server_lib.measure_rho(pipe_g, key, payloads, ids_g)
            else:
                rho_g = _measure_rho_dist(pipe_g, key, delta, ids_g, pre_state)
        elif cfg.backend in ("gspmd", "shard_map"):
            dec, cstate, nbytes_g, intra_g, delta = _group_dist(
                pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate, cfg
            )
            bytes_sent += nbytes_g
            intra_pod += intra_g
            if pipe_g.code_stage is None:
                coded_sent += nbytes_g
            else:
                coded_sent += coded_payload_nbytes(
                    pipe_g,
                    _rederive_payloads(pipe_g, key, delta, ids_g, pre_state),
                )
            rho_g = (
                _measure_rho_dist(pipe_g, key, delta, ids_g, pre_state)
                if track else None
            )
        else:
            raise ValueError(f"unknown backend {cfg.backend!r}")
        w = len(ids_g) / n_eff
        mean_chunks = dec * w if mean_chunks is None else mean_chunks + dec * w
        if rho_g is not None:
            rho_parts.append((rho_g, len(ids_g)))

    # one EMA step per ROUND: combine the groups' measurements weighted by
    # participant count (more clients => tighter estimate)
    rho_round = None
    if rho_parts:
        wsum = sum(w for _, w in rho_parts)
        rho_round = sum(r * w for r, w in rho_parts) / wsum
        server_lib.ema_update(state_srv, rho_round, gamma=cfg.r_gamma)
    return mean_chunks, bytes_sent, coded_sent, intra_pod, rho_round, cstate


def _stale_arrival_bytes(pipe, buf: _StaleBuffer, cohort, n_chunks: int) -> int:
    """Wire bytes of ALL late arrivals in ``buf`` — every payload that lands
    is ledgered, whether the decode admits it or a fresh report supersedes
    it (the transmission happened either way)."""
    return sum(
        pipe.with_budget(k_g).payload_nbytes(n_chunks) * len(ids_g)
        for k_g, ids_g in cohort.budget_groups(buf.ids, pipe.k)
    )


def _decode_stale(pipe, buf: _StaleBuffer, admit: np.ndarray, cohort,
                  state_srv):
    """Admit round t-1's late payloads into this round's decode.

    Re-derives the admitted stragglers' payloads from the buffered encode
    inputs (their OWN round key / side information — encode is deterministic
    in them), tags them ``staleness=1``, and decodes per budget group exactly
    like a fresh group. The stale decode is a pure server-side operation:
    the payloads already arrived, so it runs on the local pipeline path
    whatever backend carries the fresh traffic.

    Returns the stale mean (C, d_block).
    """
    pos = {int(i): j for j, i in enumerate(buf.ids)}
    n_adm = len(admit)
    mean = None
    for k_g, ids_g in cohort.budget_groups(admit, pipe.k):
        if len(ids_g) == 0:
            continue
        pipe_g = server_lib.resolve_pipeline(
            pipe.with_budget(k_g), state_srv, len(ids_g)
        )
        sel = np.asarray([pos[int(i)] for i in ids_g])
        ids_j = jnp.asarray(ids_g)
        st_g = None
        if buf.mem_rows is not None:
            # per-client temporal: each straggler encoded against its OWN
            # memory snapshot (ClientState row at its encode time)
            st_g = ClientState(ef=None, memory=buf.mem_rows[sel])
        payloads, _ = pipe_g.encode_all(
            buf.key, buf.xs_rows[sel], client_ids=ids_j, side_info=buf.side,
            states=st_g,
        )
        payloads = with_staleness(payloads, 1)
        dec_side = buf.side
        if buf.mem_rows is not None:
            dec_side = jnp.mean(buf.mem_rows[sel], axis=0)
        dec = pipe_g.decode(
            buf.key, payloads, len(ids_g), client_ids=ids_j, side_info=dec_side
        )
        w = len(ids_g) / n_adm
        mean = dec * w if mean is None else mean + dec * w
    return mean


def _hier_round(pipe, rkey, xs_chunks, part, cohort, hier, cfg, cstate, side,
                mem_snapshot, stale_buf, n_chunks):
    """One hierarchical round (docs/DESIGN.md §11.2): per OWNED pod, a
    pod-local fresh sub-decode against that pod's own ``ServerState``
    (followed by that pod's stale sub-decode in async mode), then the
    cross-pod record exchange and the deterministic ascending-pod combine.

    Every process runs this with the same global inputs (task vectors,
    participation, stale buffer are deterministic replicas) but decodes only
    its owned pods; after ``exchange`` all processes hold identical records
    and reduce them identically — there is no root process.

    Returns (mean_chunks, nbytes, coded, intra_pod, dcn_info, rho_round,
    cstate, n_stale).
    """
    from ..runtime import comms as comms_lib
    from ..runtime import hierarchy as hier_lib

    plan = hier.plan
    admit = np.asarray([], dtype=part.survivors.dtype)
    if cfg.async_rounds and stale_buf is not None and cfg.staleness >= 1:
        admit = np.setdiff1d(stale_buf.ids, part.survivors)

    owned = {}
    for p in hier.owned_pods:
        part_p = Participation(sampled=plan.restrict(part.sampled, p),
                               survivors=plan.restrict(part.survivors, p))
        rec = {"n": part_p.n_survivors, "mean": None, "bytes": 0, "coded": 0,
               "intra": 0, "rho": None, "n_admit": 0, "stale_mean": None}
        if part_p.n_survivors:
            with obs.span("fl", f"pod{p}", track=f"pod{p}", pod=p,
                          survivors=part_p.n_survivors):
                dec, nb, coded_p, intra, rho_p, cstate = _decode_round(
                    pipe, rkey, xs_chunks, part_p, cohort,
                    hier.pod_states[p], cfg, cstate, side, mem_snapshot,
                )
            obs.count("runtime", "pod.decodes", pod=p)
            rec.update(mean=np.asarray(dec), bytes=int(nb),
                       coded=int(coded_p), intra=int(intra), rho=rho_p)
        admit_p = plan.restrict(admit, p)
        if len(admit_p):
            stale_p = _decode_stale(pipe, stale_buf, admit_p, cohort,
                                    hier.pod_states[p])
            rec.update(n_admit=int(len(admit_p)),
                       stale_mean=np.asarray(stale_p))
        owned[p] = rec

    records = hier.exchange.exchange(owned)
    # remote pods' wire bytes must still land on this process's trace so the
    # byte-equality gate (trace sum == History ledger) holds per process
    owned_set = set(hier.owned_pods)
    remote_bytes = sum(r["bytes"] for q, r in records.items()
                       if q not in owned_set)
    obs.marker("fl", "client_encode", track="client_encode",
               bytes=int(remote_bytes), remote=True, hierarchy="hier")

    mean_np, _, _ = hier_lib.combine_records(records)
    mean_chunks = jnp.asarray(mean_np)
    nbytes = sum(r["bytes"] for r in records.values())
    # older runtime processes may exchange records without the coded ledger;
    # a pod record lacking it is ledgered at raw (code stage absent there)
    coded = sum(r.get("coded", r["bytes"]) for r in records.values())
    intra = sum(r["intra"] for r in records.values())
    rho_round = hier_lib.combine_rho(records)

    stale_np, n_stale, _ = hier_lib.combine_records(
        records, key="stale_mean", count_key="n_admit"
    )
    stale_pods = sum(1 for q, r in records.items()
                     if q != 0 and r["n_admit"] > 0)
    dcn_info = comms_lib.cross_pod_traffic(
        pipe, cohort, part.survivors, plan, n_chunks,
        stale_pods=stale_pods, hierarchy="hier",
    )
    if n_stale:
        mean_chunks = server_lib.admit_stale(
            mean_chunks, part.n_survivors, jnp.asarray(stale_np), n_stale,
            cfg.stale_weight,
        )
    return (mean_chunks, nbytes, coded, intra, dcn_info, rho_round, cstate,
            n_stale)


def _advance_straggler_state(pipe, key, xs_chunks, stragglers, cohort, cstate):
    """Async mode: stragglers DID encode this round (late), so their
    client-held temporal memories advance exactly as a survivor's would —
    the server mirrors the update when the payload arrives next round."""
    if cstate is None or not pipe.has_client_temporal:
        return cstate
    for k_g, ids_g in cohort.budget_groups(stragglers, pipe.k):
        if len(ids_g) == 0:
            continue
        ids_j = jnp.asarray(ids_g)
        st_g = jax.tree.map(lambda a: a[ids_j], cstate)
        _, st_new = pipe.with_budget(k_g).encode_all(
            key, xs_chunks[ids_g], client_ids=ids_j, states=st_g
        )
        if st_new is not None:
            cstate = _scatter_rows(cstate, st_new, ids_j)
    return cstate


def _validate_cfg(pipe, cfg):
    if cfg.async_rounds:
        if cfg.staleness not in (0, 1):
            raise ValueError(
                f"async rounds support staleness 0 (drop late payloads) or 1 "
                f"(admit next round); got {cfg.staleness}"
            )
        if pipe.has_ef:
            raise ValueError(
                "error feedback does not compose with async rounds: the EF "
                "residual is defined by what the server RECEIVED, which is "
                "unknown while a payload is still in flight — drop the "
                "ErrorFeedback stage or run sync rounds"
            )
    if cfg.overlap:
        if pipe.stateful:
            raise ValueError(
                "overlap=True requires a stateless pipeline: EF residuals "
                "and temporal-memory updates are round-synchronous (they "
                "need the whole payload before the next round encodes)"
            )
        collectives.check_streamable(pipe)
    if cfg.ownership:
        # per-client temporal composes: the mean-memory add-back is
        # position-wise (each owner adds its slice) and the memory update
        # runs client-local from full payloads, exactly as without ownership
        collectives.check_shardable(pipe)
        if cfg.n_owners < 0:
            raise ValueError(f"n_owners must be >= 0, got {cfg.n_owners}")
    if cfg.adaptive_budgets:
        if getattr(pipe.sparsifier, "name", None) != "rand_k":
            raise ValueError(
                "adaptive_budgets rewrites rand_k's chunk_budgets vector; "
                f"the {getattr(pipe.sparsifier, 'name', '?')!r} sparsifier "
                "has no per-chunk budget mechanism"
            )
        if cfg.backend != "local" or cfg.hierarchy != "flat":
            raise ValueError(
                "adaptive_budgets requires backend='local' and "
                "hierarchy='flat': the per-round budget vector depends on "
                "the server's previous estimate, which the dist/hier routes "
                "do not rebroadcast to the encode side"
            )
        if cfg.async_rounds:
            raise ValueError(
                "adaptive_budgets does not compose with async rounds: a "
                "stale payload was encoded under the PREVIOUS round's budget "
                "vector, which the admitting round no longer holds"
            )
        if cfg.overlap or cfg.ownership:
            raise ValueError(
                "adaptive_budgets packs one flat value row per client "
                "(non-streamable, non-shardable); drop overlap/ownership"
            )
    if cfg.hierarchy not in ("flat", "hier"):
        raise ValueError(f"hierarchy must be 'flat' or 'hier', got "
                         f"{cfg.hierarchy!r}")
    if cfg.hierarchy == "hier":
        if cfg.pods < 1:
            raise ValueError(f"pods must be >= 1, got {cfg.pods}")
        if cfg.backend != "local":
            raise ValueError(
                "hierarchy='hier' requires backend='local': each pod's "
                "sub-decode drives the pipeline directly (the dist backends "
                "model ONE pod's mesh; cross-pod transport is "
                "runtime.comms)"
            )


def run_rounds(task: Task, spec, cohort: Cohort | None = None,
               cfg: RoundConfig = RoundConfig()):
    """Drive ``cfg.n_rounds`` federated rounds of ``task`` under ``spec`` (a
    codec Pipeline or sparsifier config).

    Returns (final task state, History). The recorded per-round ``mse`` is
    against the SURVIVORS' true mean — the quantity the estimator actually
    targets once stragglers are dropped; ``mse_pop`` is against ALL clients'
    current-round mean (the quantity FL ultimately wants), which is where
    admitting a late payload instead of dropping it shows up.

    Async mode (``cfg.async_rounds``): stragglers encode late; their
    payloads are buffered and admitted into the next round's decode at
    staleness 1 (``cfg.staleness=0`` drops them — the pure-scheduling
    ablation). With ``cohort.dropout == 0`` async output is bit-identical
    to sync.
    """
    pipe = as_pipeline(spec)
    cohort = cohort or Cohort(n_clients=task.n_clients)
    if cohort.n_clients != task.n_clients:
        raise ValueError("cohort and task disagree on n_clients")
    _validate_cfg(pipe, cfg)

    key = jax.random.key(cfg.seed)
    state = task.init(key)
    state_srv = server_lib.ServerState()
    hist = History()
    n_chunks = chunking.num_chunks(task.dim, pipe.d_block)
    cstate = cohort.init_state(pipe, n_chunks)
    stale_buf: _StaleBuffer | None = None

    hier = None
    if cfg.hierarchy == "hier":
        # lazy import: runtime.hierarchy imports fl.server, so the module
        # edge must point runtime -> fl at import time, fl -> runtime only
        # at call time
        from ..runtime import hierarchy as hier_lib

        hier = hier_lib.HierarchicalAggregator(
            hier_lib.PodPlan(cohort.n_clients, cfg.pods), ctx=cfg.runtime
        )

    for t in range(cfg.n_rounds):
        tr = obs.current_tracer()
        if tr is not None:
            tr.set_round(t)
        round_span = obs.span("fl", "round", track="round")
        rsp = round_span.__enter__()
        rkey = jax.random.fold_in(key, t)
        vecs = task.client_vectors(state, rkey)  # (n, dim)
        part = cohort.sample_round(cfg.seed, t)
        xs_chunks = jax.vmap(lambda v: chunking.chunk(v, pipe.d_block))(vecs)
        side, mem_snapshot = _side_and_memory(pipe, cfg, state_srv, cstate)

        if hier is not None:
            (mean_chunks, nbytes, coded, intra_pod, dcn_info, rho_round,
             cstate, n_stale) = _hier_round(
                pipe, rkey, xs_chunks, part, cohort, hier, cfg, cstate,
                side, mem_snapshot, stale_buf, n_chunks,
            )
            dcn = dcn_info["dcn_bytes"]
        else:
            (mean_chunks, nbytes, coded, intra_pod, rho_round,
             cstate) = _decode_round(
                pipe, rkey, xs_chunks, part, cohort, state_srv, cfg, cstate,
                side, mem_snapshot,
            )
            dcn = 0
        # intra-pod and DCN traffic are modelled tier quantities, deliberately
        # keyed ``bytes_intra_pod`` / ``bytes_dcn`` so they never enter the
        # wire-ledger sum
        obs.marker("fl", "payload_route", track="payload_route",
                   bytes_intra_pod=intra_pod, bytes_dcn=dcn,
                   backend=cfg.backend)

        # ---- staleness-1 admission: last round's late payloads land now.
        # EVERY arrival is ledgered (it crossed the wire), but a client that
        # ALSO reported fresh this round supersedes its own stale payload —
        # the fresh one carries strictly newer information, so only the
        # non-superseded set enters the decode. (Hierarchical rounds already
        # decoded and combined the admitted groups per pod inside
        # ``_hier_round``; only the arrival ledger lands here.)
        with obs.span("fl", "stale_admission", track="stale_admission") as ssp:
            stale_nbytes = 0
            if hier is None:
                n_stale = 0
            if cfg.async_rounds and stale_buf is not None and cfg.staleness >= 1:
                stale_nbytes = _stale_arrival_bytes(pipe, stale_buf, cohort,
                                                    n_chunks)
                nbytes += stale_nbytes
                # stale arrivals enter the coded ledger at raw size (see the
                # History.coded_bytes comment)
                coded += stale_nbytes
                if hier is None:
                    admit = np.setdiff1d(stale_buf.ids, part.survivors)
                    if len(admit):
                        stale_mean = _decode_stale(
                            pipe, stale_buf, admit, cohort, state_srv
                        )
                        n_stale = len(admit)
                        mean_chunks = server_lib.admit_stale(
                            mean_chunks, part.n_survivors, stale_mean,
                            n_stale, cfg.stale_weight,
                        )
            ssp["bytes"] = stale_nbytes
            ssp["admitted"] = n_stale

        # ---- this round's stragglers encode NOW (overlapping the server's
        # decode above); buffer their encode inputs for admission at t+1.
        # staleness=0 drops late payloads entirely: no buffer, and no state
        # advance either (a payload the server never sees must not move the
        # memory mirror) — exactly the sync drop semantics.
        if cfg.async_rounds and cfg.staleness >= 1 and len(part.stragglers):
            strag_j = jnp.asarray(part.stragglers)
            stale_buf = _StaleBuffer(
                key=rkey, ids=part.stragglers, xs_rows=xs_chunks[strag_j],
                side=side,
                mem_rows=None if mem_snapshot is None else mem_snapshot[strag_j],
            )
            # hierarchical multi-process: a process mirrors only its owned
            # pods' client rows (non-owned rows are never read here — pod
            # ownership is static, so their encodes happen elsewhere)
            strag_adv = part.stragglers
            if hier is not None:
                strag_adv = strag_adv[np.isin(strag_adv,
                                              hier.owned_clients())]
            cstate = _advance_straggler_state(
                pipe, rkey, xs_chunks, strag_adv, cohort, cstate
            )
        else:
            stale_buf = None

        true_mean = jnp.mean(xs_chunks[part.survivors], axis=0)
        hist.mse.append(float(correlation.mse(mean_chunks, true_mean)))
        hist.mse_pop.append(
            float(correlation.mse(mean_chunks, jnp.mean(xs_chunks, axis=0)))
        )
        hist.bytes.append(int(nbytes))
        hist.coded_bytes.append(int(coded))
        hist.n_survivors.append(part.n_survivors)
        hist.n_sampled.append(part.n_sampled)
        hist.n_stale.append(n_stale)
        hist.stale_bytes.append(int(stale_nbytes))
        hist.intra_pod_bytes.append(int(intra_pod))
        hist.dcn_bytes.append(int(dcn))
        hist.rho_hat.append(float("nan") if rho_round is None else rho_round)

        with obs.span("fl", "temporal_update", track="temporal_update",
                      temporal=bool(cfg.temporal or pipe.temporal_stage)):
            server_lib.commit_round(state_srv, mean_chunks)
        mean = chunking.unchunk(mean_chunks, task.dim)
        state = task.step(state, mean)
        hist.metric.append(
            float("nan") if task.metric is None else task.metric(state)
        )
        rsp["mse"] = hist.mse[-1]
        rsp["wire_bytes"] = nbytes
        # coded ledger rides the round summary under its own key so the
        # trace's exact ``bytes`` sum (client_encode/stale_admission only)
        # stays untouched; tools/trace_report.py cross-checks it against
        # metadata.ledger_coded_bytes when present
        rsp["bytes_coded"] = int(coded)
        rsp["survivors"] = part.n_survivors
        round_span.__exit__(None, None, None)

    tr = obs.current_tracer()
    if tr is not None:
        tr.set_round(None)
    hist.client_state = cstate
    return state, hist
