"""The federated round driver: encode -> ledger -> decode -> task step.

One ``run_rounds`` call plays ``n_rounds`` of

    1. clients compute their round vectors          (task.client_vectors)
    2. the server samples participants; some drop   (cohort.sample_round)
    3. survivors chunk + encode (optionally against the server's previous
       estimate — temporal side information)        (core.estimators)
    4. every transmitted payload byte is ledgered   (Konecny & Richtarik-style
       accuracy-vs-communication accounting)
    5. the server decodes the survivors' mean — renormalising by who actually
       reported, with their actual client ids, per budget group
    6. the server updates its correlation tracker and temporal state
    7. the task advances                            (task.step)

Backends: "local" drives core.estimators directly (CPU-friendly, supports
heterogeneous per-client budgets); "gspmd" and "shard_map" route step 3-5
through repro.dist.collectives on a mesh (uniform budgets) — the same math,
with payload-sized cross-device traffic on the shard_map path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import chunking, correlation
from ..core.estimators import base as est_base
from ..dist import collectives
from . import server as server_lib
from .clients import Cohort
from .tasks import Task


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    n_rounds: int = 20
    seed: int = 0
    temporal: bool = False      # decode deltas against the previous estimate
    track_r: bool | None = None  # default: only for transform="wavg"
    r_gamma: float = 0.3
    backend: str = "local"      # local | gspmd | shard_map
    mesh: Any = None            # required for gspmd / shard_map
    client_axes: tuple = ("pod",)


@dataclasses.dataclass
class History:
    """Per-round trajectory + ledger. Lists are length n_rounds."""

    metric: list = dataclasses.field(default_factory=list)
    mse: list = dataclasses.field(default_factory=list)      # vs survivors' true mean
    bytes: list = dataclasses.field(default_factory=list)    # transmitted this round
    n_survivors: list = dataclasses.field(default_factory=list)
    n_sampled: list = dataclasses.field(default_factory=list)
    rho_hat: list = dataclasses.field(default_factory=list)  # tracker output (or nan)

    @property
    def total_bytes(self) -> int:
        return int(np.sum(self.bytes))

    def bytes_to_target(self, target: float, key: str = "metric") -> int | None:
        """Cumulative bytes when the metric first reaches <= target."""
        vals, cum = getattr(self, key), np.cumsum(self.bytes)
        for v, b in zip(vals, cum):
            if v is not None and not np.isnan(v) and v <= target:
                return int(b)
        return None


def _payload_bytes(payloads) -> int:
    return collectives.payload_nbytes_per_client(payloads)


def _should_track(spec, cfg) -> bool:
    return cfg.track_r if cfg.track_r is not None else spec.transform == "wavg"


def _decode_local(spec, key, xs_chunks, part, cohort, state_srv, cfg):
    """Budget-grouped encode/decode over the survivors. xs_chunks: (n, C, d).

    Returns (mean_chunks, bytes_sent, rho_round)."""
    side = server_lib.side_info_for(spec, state_srv, cfg.temporal)
    groups = cohort.budget_groups(part.survivors, spec.k)
    track = _should_track(spec, cfg)
    n_eff = part.n_survivors
    mean_chunks, bytes_sent, rho_parts = None, 0, []
    for k_g, ids_g in groups:
        if len(ids_g) == 0:
            continue
        spec_g = server_lib.resolve_spec(spec.replace(k=k_g), state_srv, len(ids_g))
        ids_j = jnp.asarray(ids_g)
        payloads = est_base.encode_all(
            spec_g, key, xs_chunks[ids_g], client_ids=ids_j, side_info=side
        )
        bytes_sent += _payload_bytes(payloads) * len(ids_g)
        dec = est_base.decode(
            spec_g, key, payloads, len(ids_g), client_ids=ids_j, side_info=side
        )
        w = len(ids_g) / n_eff
        mean_chunks = dec * w if mean_chunks is None else mean_chunks + dec * w
        if track:
            rho_g = server_lib.measure_rho(spec_g, key, payloads, ids_g)
            if rho_g is not None:
                rho_parts.append((rho_g, len(ids_g)))
    # one EMA step per ROUND: combine the groups' measurements weighted by
    # participant count (more clients => tighter estimate)
    rho_round = None
    if rho_parts:
        wsum = sum(w for _, w in rho_parts)
        rho_round = sum(r * w for r, w in rho_parts) / wsum
        server_lib.ema_update(state_srv, rho_round, gamma=cfg.r_gamma)
    return mean_chunks, bytes_sent, rho_round


def _decode_dist(spec, key, xs_chunks, part, state_srv, cfg, ef_chunks=None):
    """Collectives-backed decode (uniform budgets): the gspmd/shard_map
    backends, and the local backend whenever spec.ef is set (error-feedback
    residual threading lives in dist.collectives; without a mesh the gspmd
    path is plain single-process math)."""
    side = server_lib.side_info_for(spec, state_srv, cfg.temporal)
    spec_r = server_lib.resolve_spec(spec, state_srv, part.n_survivors)
    delta = xs_chunks if side is None else xs_chunks - side[None]
    tree = {"x": delta}
    if cfg.backend == "shard_map":
        if cfg.mesh is None:
            raise ValueError("backend='shard_map' needs cfg.mesh")
        mean_tree, info, ef_next = collectives.compressed_mean_tree_shardmap(
            spec_r, key, tree, cfg.mesh, client_axes=cfg.client_axes,
            participants=part.survivors, ef_chunks=ef_chunks,
        )
    else:
        shardings = collectives.dme_shardings(cfg.mesh, cfg.client_axes)
        mean_tree, info, ef_next = collectives.compressed_mean_tree(
            spec_r, key, tree, shardings, participants=part.survivors,
            ef_chunks=ef_chunks,
        )
    mean_chunks = mean_tree["x"]
    if side is not None:
        mean_chunks = mean_chunks + side
    rho_round = None
    if _should_track(spec, cfg):
        # the collectives paths keep payloads internal, so the tracker
        # re-derives them (same key/ids/side/residual => identical payloads).
        # Costs one extra encode of the survivors — payload-sized, server-side.
        ids = part.survivors
        enc_in = delta[ids]
        if spec_r.ef and ef_chunks is not None:
            enc_in = enc_in + ef_chunks[ids]
        payloads = est_base.encode_all(
            spec_r, key, enc_in, client_ids=jnp.asarray(ids)
        )
        rho_round = server_lib.measure_rho(spec_r, key, payloads, ids)
        if rho_round is not None:
            server_lib.ema_update(state_srv, rho_round, gamma=cfg.r_gamma)
    return mean_chunks, info["bytes_sent"], rho_round, ef_next


def run_rounds(task: Task, spec, cohort: Cohort | None = None,
               cfg: RoundConfig = RoundConfig()):
    """Drive ``cfg.n_rounds`` federated rounds of ``task`` under ``spec``.

    Returns (final task state, History). The recorded per-round ``mse`` is
    against the SURVIVORS' true mean — the quantity the estimator actually
    targets once stragglers are dropped.
    """
    cohort = cohort or Cohort(n_clients=task.n_clients)
    if cohort.n_clients != task.n_clients:
        raise ValueError("cohort and task disagree on n_clients")
    if cohort.budgets is not None and cfg.backend != "local":
        raise ValueError("heterogeneous budgets require backend='local'")
    if spec.ef and cohort.budgets is not None:
        raise ValueError("error feedback with heterogeneous budgets is not "
                         "supported yet (see ROADMAP)")

    key = jax.random.key(cfg.seed)
    state = task.init(key)
    state_srv = server_lib.ServerState()
    hist = History()
    ef_chunks = None  # (n, C, d_block) residuals, threaded when spec.ef

    for t in range(cfg.n_rounds):
        rkey = jax.random.fold_in(key, t)
        vecs = task.client_vectors(state, rkey)  # (n, dim)
        part = cohort.sample_round(cfg.seed, t)
        xs_chunks = jax.vmap(lambda v: chunking.chunk(v, spec.d_block))(vecs)

        if cfg.backend == "local" and not spec.ef:
            mean_chunks, nbytes, rho_round = _decode_local(
                spec, rkey, xs_chunks, part, cohort, state_srv, cfg
            )
        elif cfg.backend in ("local", "gspmd", "shard_map"):
            # EF residual threading always goes through dist.collectives
            # (without a mesh the gspmd path is plain single-process math)
            mean_chunks, nbytes, rho_round, ef_chunks = _decode_dist(
                spec, rkey, xs_chunks, part, state_srv, cfg,
                ef_chunks=ef_chunks,
            )
        else:
            raise ValueError(f"unknown backend {cfg.backend!r}")

        true_mean = jnp.mean(xs_chunks[part.survivors], axis=0)
        hist.mse.append(float(correlation.mse(mean_chunks, true_mean)))
        hist.bytes.append(int(nbytes))
        hist.n_survivors.append(part.n_survivors)
        hist.n_sampled.append(part.n_sampled)
        hist.rho_hat.append(float("nan") if rho_round is None else rho_round)

        server_lib.commit_round(state_srv, mean_chunks)
        mean = chunking.unchunk(mean_chunks, task.dim)
        state = task.step(state, mean)
        hist.metric.append(
            float("nan") if task.metric is None else task.metric(state)
        )

    return state, hist
