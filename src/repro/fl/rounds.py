"""The federated round driver: encode -> ledger -> decode -> task step.

One ``run_rounds`` call plays ``n_rounds`` of

    1. clients compute their round vectors          (task.client_vectors)
    2. the server samples participants; some drop   (cohort.sample_round)
    3. survivors chunk + encode through the codec pipeline — optionally
       against side information: the server's previous estimate (broadcast
       temporal) or each client's own memory in ClientState (true per-client
       Rand-k-Temporal)                             (core.codec)
    4. every transmitted payload byte is ledgered straight off the payload's
       self-described schema                        (Konecny & Richtarik-style
       accuracy-vs-communication accounting)
    5. the server decodes the survivors' mean — renormalising by who actually
       reported, with their actual client ids, per budget group
    6. the server updates its correlation tracker and temporal state
    7. the task advances                            (task.step)

``spec`` may be a ``codec.Pipeline``, a bare sparsifier config, or the
deprecated ``EstimatorSpec``. Heterogeneous budgets and error feedback
compose on EVERY backend now: budget groups are decoded independently (the
group's budget rides in each payload's meta), EF residual rows live per
client in ``ClientState.ef`` and follow their own k_i.

Backends: "local" drives the pipeline directly (CPU-friendly; the only
backend for per-client temporal memories, which need the driver to mirror
each client's state); "gspmd" and "shard_map" route steps 3-5 through
repro.dist.collectives on a mesh — the same math, with payload-sized
cross-device traffic on the shard_map path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import chunking, correlation
from ..core.codec import ClientState, as_pipeline
from ..dist import collectives
from . import server as server_lib
from .clients import Cohort
from .tasks import Task


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    n_rounds: int = 20
    seed: int = 0
    temporal: bool = False      # broadcast temporal: decode deltas vs prev estimate
    track_r: bool | None = None  # default: only for transform="wavg"
    r_gamma: float = 0.3
    backend: str = "local"      # local | gspmd | shard_map
    mesh: Any = None            # required for gspmd / shard_map
    client_axes: tuple = ("pod",)


@dataclasses.dataclass
class History:
    """Per-round trajectory + ledger. Lists are length n_rounds."""

    metric: list = dataclasses.field(default_factory=list)
    mse: list = dataclasses.field(default_factory=list)      # vs survivors' true mean
    bytes: list = dataclasses.field(default_factory=list)    # transmitted this round
    n_survivors: list = dataclasses.field(default_factory=list)
    n_sampled: list = dataclasses.field(default_factory=list)
    rho_hat: list = dataclasses.field(default_factory=list)  # tracker output (or nan)
    client_state: Any = None  # final stacked ClientState (None if stateless)

    @property
    def total_bytes(self) -> int:
        return int(np.sum(self.bytes))

    def bytes_to_target(self, target: float, key: str = "metric") -> int | None:
        """Cumulative bytes when the metric first reaches <= target."""
        vals, cum = getattr(self, key), np.cumsum(self.bytes)
        for v, b in zip(vals, cum):
            if v is not None and not np.isnan(v) and v <= target:
                return int(b)
        return None


def _should_track(pipe, cfg) -> bool:
    return cfg.track_r if cfg.track_r is not None else pipe.transform == "wavg"


def _scatter_rows(full, rows, ids_j):
    """Scatter updated per-client rows (a ClientState slice) back into the
    full stacked state; None subtrees pass through."""
    return jax.tree.map(lambda f, r: f.at[ids_j].set(r), full, rows)


def _group_local(pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate):
    """One budget group on the local backend. Returns (group mean, updated
    full ClientState, stacked payloads for the tracker)."""
    ids_j = jnp.asarray(ids_g)
    st_g = None
    if cstate is not None:
        st_g = jax.tree.map(lambda a: a[ids_j], cstate)
    payloads, st_new = pipe_g.encode_all(
        key, xs_chunks[ids_g], client_ids=ids_j, side_info=side, states=st_g
    )
    if st_new is not None:
        cstate = _scatter_rows(cstate, st_new, ids_j)
    dec_side = side
    if mem_snapshot is not None:
        # per-client temporal: the server adds back the SURVIVORS' mean
        # memory (its mirror of the clients' side information)
        dec_side = jnp.mean(mem_snapshot[ids_j], axis=0)
    dec = pipe_g.decode(
        key, payloads, len(ids_g), client_ids=ids_j, side_info=dec_side
    )
    return dec, cstate, payloads


def _group_dist(pipe_g, key, xs_chunks, ids_g, side, cstate, cfg):
    """One budget group through dist.collectives (gspmd / shard_map)."""
    delta = xs_chunks if side is None else xs_chunks - side[None]
    tree = {"x": delta}
    ef_arr = cstate.ef if (cstate is not None and pipe_g.has_ef) else None
    if cfg.backend == "shard_map":
        if cfg.mesh is None:
            raise ValueError("backend='shard_map' needs cfg.mesh")
        mean_tree, info, ef_next = collectives.compressed_mean_tree_shardmap(
            pipe_g, key, tree, cfg.mesh, client_axes=cfg.client_axes,
            participants=ids_g, ef_chunks=ef_arr,
        )
    else:
        shardings = collectives.dme_shardings(cfg.mesh, cfg.client_axes)
        mean_tree, info, ef_next = collectives.compressed_mean_tree(
            pipe_g, key, tree, shardings, participants=ids_g, ef_chunks=ef_arr,
        )
    if ef_next is not None:
        cstate = ClientState(ef=ef_next, memory=cstate.memory)
    mean_g = mean_tree["x"]
    if side is not None:
        mean_g = mean_g + side
    return mean_g, cstate, info["bytes_sent"], delta


def _measure_rho_dist(pipe_g, key, delta, ids_g, cstate):
    """The collectives paths keep payloads internal, so the tracker re-derives
    them (same key/ids/side/residual => identical payloads). Costs one extra
    encode of the group's survivors — payload-sized, server-side."""
    ids_j = jnp.asarray(ids_g)
    enc_in = delta[ids_g]
    if pipe_g.has_ef and cstate is not None and cstate.ef is not None:
        # ``cstate`` is the PRE-update state (the residual the clients added
        # before encoding), so the re-derived payloads match what was sent.
        enc_in = enc_in + cstate.ef[ids_j]
    payloads, _ = pipe_g.encode_all(key, enc_in, client_ids=ids_j)
    return server_lib.measure_rho(pipe_g, key, payloads, ids_g)


def _decode_round(pipe, key, xs_chunks, part, cohort, state_srv, cfg, cstate):
    """Budget-grouped encode/decode over the survivors on any backend.

    Returns (mean_chunks, bytes_sent, rho_round, cstate)."""
    groups = cohort.budget_groups(part.survivors, pipe.k)
    track = _should_track(pipe, cfg)
    n_eff = part.n_survivors
    n_chunks = xs_chunks.shape[1]

    mem_snapshot = None
    side = None
    if pipe.has_client_temporal:
        mem_snapshot = cstate.memory  # pre-update: what clients encode against
    elif cfg.temporal or (pipe.temporal_stage is not None):
        side = server_lib.side_info_for(state_srv, temporal=True)

    mean_chunks, bytes_sent, rho_parts = None, 0, []
    for k_g, ids_g in groups:
        if len(ids_g) == 0:
            continue
        pre_state = cstate
        pipe_g = server_lib.resolve_pipeline(
            pipe.with_budget(k_g), state_srv, len(ids_g)
        )
        if cfg.backend == "local":
            dec, cstate, payloads = _group_local(
                pipe_g, key, xs_chunks, ids_g, side, mem_snapshot, cstate
            )
            bytes_sent += pipe_g.payload_nbytes(n_chunks) * len(ids_g)
            rho_g = (
                server_lib.measure_rho(pipe_g, key, payloads, ids_g)
                if track else None
            )
        elif cfg.backend in ("gspmd", "shard_map"):
            dec, cstate, nbytes_g, delta = _group_dist(
                pipe_g, key, xs_chunks, ids_g, side, cstate, cfg
            )
            bytes_sent += nbytes_g
            rho_g = (
                _measure_rho_dist(pipe_g, key, delta, ids_g, pre_state)
                if track else None
            )
        else:
            raise ValueError(f"unknown backend {cfg.backend!r}")
        w = len(ids_g) / n_eff
        mean_chunks = dec * w if mean_chunks is None else mean_chunks + dec * w
        if rho_g is not None:
            rho_parts.append((rho_g, len(ids_g)))

    # one EMA step per ROUND: combine the groups' measurements weighted by
    # participant count (more clients => tighter estimate)
    rho_round = None
    if rho_parts:
        wsum = sum(w for _, w in rho_parts)
        rho_round = sum(r * w for r, w in rho_parts) / wsum
        server_lib.ema_update(state_srv, rho_round, gamma=cfg.r_gamma)
    return mean_chunks, bytes_sent, rho_round, cstate


def run_rounds(task: Task, spec, cohort: Cohort | None = None,
               cfg: RoundConfig = RoundConfig()):
    """Drive ``cfg.n_rounds`` federated rounds of ``task`` under ``spec`` (a
    codec Pipeline, sparsifier config, or deprecated EstimatorSpec).

    Returns (final task state, History). The recorded per-round ``mse`` is
    against the SURVIVORS' true mean — the quantity the estimator actually
    targets once stragglers are dropped.
    """
    pipe = as_pipeline(spec)
    cohort = cohort or Cohort(n_clients=task.n_clients)
    if cohort.n_clients != task.n_clients:
        raise ValueError("cohort and task disagree on n_clients")
    if pipe.has_client_temporal and cfg.backend != "local":
        raise ValueError(
            "per-client temporal memories (codec.Temporal(per_client=True)) "
            "require backend='local': the driver mirrors each client's "
            "ClientState row"
        )

    key = jax.random.key(cfg.seed)
    state = task.init(key)
    state_srv = server_lib.ServerState()
    hist = History()
    n_chunks = chunking.num_chunks(task.dim, pipe.d_block)
    cstate = cohort.init_state(pipe, n_chunks)

    for t in range(cfg.n_rounds):
        rkey = jax.random.fold_in(key, t)
        vecs = task.client_vectors(state, rkey)  # (n, dim)
        part = cohort.sample_round(cfg.seed, t)
        xs_chunks = jax.vmap(lambda v: chunking.chunk(v, pipe.d_block))(vecs)

        mean_chunks, nbytes, rho_round, cstate = _decode_round(
            pipe, rkey, xs_chunks, part, cohort, state_srv, cfg, cstate
        )

        true_mean = jnp.mean(xs_chunks[part.survivors], axis=0)
        hist.mse.append(float(correlation.mse(mean_chunks, true_mean)))
        hist.bytes.append(int(nbytes))
        hist.n_survivors.append(part.n_survivors)
        hist.n_sampled.append(part.n_sampled)
        hist.rho_hat.append(float("nan") if rho_round is None else rho_round)

        server_lib.commit_round(state_srv, mean_chunks)
        mean = chunking.unchunk(mean_chunks, task.dim)
        state = task.step(state, mean)
        hist.metric.append(
            float("nan") if task.metric is None else task.metric(state)
        )

    hist.client_state = cstate
    return state, hist
