"""Server-side round state: online correlation tracking + temporal decoding.

Two FL-specific capabilities live here, both consuming only what the server
legitimately sees (payloads and its own decode history):

1. **Online cross-client correlation tracking** — the Rand-Proj-Spatial(Opt)
   transform needs the true correlation R (paper Eq. 7), which no real server
   knows. After each decode we reconstruct every participant's unbiased
   contribution with the codec's ``self_decode`` (the server's view of client
   i), apply ``core.correlation.r_exact`` to that decoded history, and track
   an EMA across rounds. The cross terms of r_exact are unbiased (independent
   per-client randomness), but compression noise inflates the denominator
   Sum ||x_hat_i||^2 by exactly d/k for the Rand-k / SRHT family
   (G G^T = I_k for SRHT rows, so E||G^T G x||^2 = (k/d) d/k^2 ... = (d/k)
   ||x||^2), so we rescale by that known factor before the EMA. Residual
   ratio bias is small and toward 0 — the tracker underclaims, never
   overclaims, correlation.

2. **The practical Rand-Proj-Spatial(wavg) variant** — when true correlation
   is unavailable, ``transform="wavg"`` resolves per round to
   Opt(r_value=R_ema) once the tracker warms up, falling back to the paper's
   Avg interpolation for the first rounds. Resolution happens here, before
   any decode graph is built (core.transforms rejects raw "wavg").

3. **Temporal-correlation decoding** (à la Rand-k-Temporal, Jhunjhunwala et
   al. 2021) — the server's previous-round estimate is the side information:
   clients encode x_i - y_{t-1}, the server decodes the delta mean and adds
   y_{t-1} back (core.estimators ``side_info`` hook). On slowly-drifting
   workloads ||x_i - y_{t-1}|| << ||x_i||, so the same payload bytes buy a
   much smaller MSE; the spatial transform then exploits whatever cross-
   client correlation the *deltas* retain.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import correlation
from ..core.estimators import base as est_base


@dataclasses.dataclass
class ServerState:
    """Mutable per-run server state threaded through rounds."""

    round: int = 0
    prev_mean: jnp.ndarray | None = None   # (C, d_block) last decoded chunks
    r_ema: float | None = None             # EMA of the online R estimate
    r_history: list = dataclasses.field(default_factory=list)


def resolve_spec(spec, state: ServerState, n_eff: int):
    """Round-level resolution of the practical wavg variant.

    wavg -> Opt(R_ema) once correlation history exists, else Avg. R is
    re-expressed for the round's participant count: r_exact was measured over
    n_meas clients but rho = R/(n_eff - 1) must use this round's n_eff, so we
    track rho directly (see update_correlation) and scale back.
    """
    if n_eff < 2:
        # singleton decode: no cross-client correlation to exploit, and the
        # avg/opt interpolations are undefined at n=1 (rho = R/(n-1))
        return spec.replace(transform="one", r_value=None)
    if spec.transform != "wavg":
        return spec
    if state.r_ema is None:
        return spec.replace(transform="avg")
    r = float(np.clip(state.r_ema, 0.0, 1.0)) * (n_eff - 1.0)
    return spec.replace(transform="opt", r_value=r)


def side_info_for(spec, state: ServerState, temporal: bool):
    """Previous-round estimate as side information (None on round 0)."""
    if not temporal or state.prev_mean is None:
        return None
    return state.prev_mean


def measure_rho(spec, key, payloads, ids) -> float | None:
    """One group's rho = R/(n-1) measurement from this round's payloads.

    Reconstructs each participant's unbiased contribution via self_decode and
    measures r_exact over the stack. Returns the estimate (rho, in [0, 1]) or
    None when the codec has no per-client reconstruction or n < 2. Pure
    measurement — the cross-round EMA is ``ema_update`` (one step per round,
    however many budget groups contributed).
    """
    codec = est_base.get(spec.name)
    if codec.self_decode is None:
        return None
    n = len(ids)
    if n < 2:
        return None
    id_arr = jnp.asarray(np.asarray(ids))
    recon = jax.vmap(
        lambda i, p: est_base.self_decode(spec, key, i, p)
    )(id_arr, payloads)  # (n, C, d)
    # de-inflate the denominator: E||self_decode||^2 = (d/k) ||x||^2 for the
    # unbiased sparsifying family, = ||x||^2 for the identity baseline
    scale = 1.0
    if spec.name in ("rand_k", "rand_k_spatial", "rand_proj_spatial"):
        scale = spec.d_block / spec.k
    r_round = float(correlation.r_exact(recon)) * scale
    return float(np.clip(r_round / (n - 1.0), 0.0, 1.0))


def ema_update(state: ServerState, rho_round: float, gamma: float = 0.3) -> None:
    """Advance the cross-round tracker by exactly one EMA step."""
    state.r_ema = (
        rho_round if state.r_ema is None
        else (1.0 - gamma) * state.r_ema + gamma * rho_round
    )
    state.r_history.append(rho_round)


def commit_round(state: ServerState, mean_chunks) -> None:
    """Store the decoded mean as next round's temporal side information."""
    state.prev_mean = mean_chunks
    state.round += 1
