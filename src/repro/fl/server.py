"""Server-side round state: online correlation tracking + temporal decoding.

Two FL-specific capabilities live here, both consuming only what the server
legitimately sees (payloads and its own decode history):

1. **Online cross-client correlation tracking** — the Rand-Proj-Spatial(Opt)
   transform needs the true correlation R (paper Eq. 7), which no real server
   knows. After each decode we reconstruct every participant's unbiased
   contribution with the pipeline's ``self_decode`` (the server's view of
   client i), apply ``core.correlation.r_exact`` to that decoded history, and
   track an EMA across rounds. The cross terms of r_exact are unbiased
   (independent per-client randomness), but compression noise inflates the
   denominator Sum ||x_hat_i||^2 by each codec's known second-moment factor
   (d/k for the Rand-k / SRHT family where G G^T = I_k; the density-corrected
   (d/k)(1 + (k-1)/d + 2(nnz-1)/(nnz d)) for SparseProj's with-replacement
   rows), declared by ``codec.Sparsifier.self_decode_norm_inflation`` and
   rescaled out before the EMA. Residual ratio bias is small and toward 0 —
   the tracker underclaims, never overclaims, correlation.

2. **The practical Rand-Proj-Spatial(wavg) variant** — when true correlation
   is unavailable, ``transform="wavg"`` resolves per round to
   Opt(r_value=R_ema) once the tracker warms up, falling back to the paper's
   Avg interpolation for the first rounds. Resolution happens here, before
   any decode graph is built (core.transforms rejects raw "wavg"):
   ``resolve_pipeline`` rewrites the pipeline's SPARSIFIER config — the
   stage-based API makes the rewrite local to one stage.

3. **Temporal-correlation decoding** (à la Rand-k-Temporal, Jhunjhunwala et
   al. 2021) — the broadcast variant: the server's previous-round estimate is
   everyone's side information; clients encode x_i - y_{t-1}, the server adds
   y_{t-1} back to the decoded delta mean. TRUE per-client temporal memories
   live in ``codec.ClientState`` (a ``Temporal`` stage in the pipeline) and
   are driven by ``fl.rounds`` — the server's role there is adding back the
   survivors' mean memory and mirroring the deterministic memory updates.

4. **Stale-payload admission** (async rounds, docs/DESIGN.md §9.2) —
   ``admit_stale`` re-weights an admitted staleness-1 group's decode into
   the fresh survivors' mean by client count. The admission decode itself
   runs in ``fl.rounds`` (with the stale group's own round key and side
   information); the combine is the server-side policy knob.

5. **Sharded-decode accounting** (``RoundConfig(ownership=True)``,
   docs/DESIGN.md §10) — ``intra_pod_reduction`` reads the all-gather vs
   chunk-ownership server-side traffic ratio off a ``dist.collectives``
   info dict; ``fl.rounds`` ledgers the per-round column in
   ``History.intra_pod_bytes``. The ownership decode composes transparently
   with everything here: ``resolve_pipeline`` rewrites the sparsifier BEFORE
   the decode is partitioned, the correlation tracker re-derives payloads
   from full client vectors (never from an owner's slice), and the stale
   decode is a whole-vector server-side op whatever routes the fresh
   traffic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import correlation
from ..core.codec import as_pipeline


@dataclasses.dataclass
class ServerState:
    """Mutable per-run server state threaded through rounds."""

    round: int = 0
    prev_mean: jnp.ndarray | None = None   # (C, d_block) last decoded chunks
    r_ema: float | None = None             # EMA of the online R estimate
    r_history: list = dataclasses.field(default_factory=list)


def resolve_pipeline(pipe, state: ServerState, n_eff: int):
    """Round-level resolution of the practical wavg variant.

    wavg -> Opt(R_ema) once correlation history exists, else Avg. R is
    re-expressed for the round's participant count: r_exact was measured over
    n_meas clients but rho = R/(n_eff - 1) must use this round's n_eff, so we
    track rho directly (see ema_update) and scale back. Sparsifiers without a
    ``transform`` field (rand_k, top_k, ...) pass through untouched.
    """
    pipe = as_pipeline(pipe)
    if n_eff < 2:
        # singleton decode: no cross-client correlation to exploit, and the
        # avg/opt interpolations are undefined at n=1 (rho = R/(n-1))
        return pipe.replace_sparsifier(
            _ignore_missing=True, transform="one", r_value=None
        )
    if pipe.transform != "wavg":
        return pipe
    if state.r_ema is None:
        return pipe.replace_sparsifier(transform="avg")
    r = float(np.clip(state.r_ema, 0.0, 1.0)) * (n_eff - 1.0)
    return pipe.replace_sparsifier(transform="opt", r_value=r)


# deprecated-name alias (pre-pipeline API); accepts spec or pipeline, returns
# a Pipeline either way.
resolve_spec = resolve_pipeline


def side_info_for(state: ServerState, temporal: bool):
    """Previous-round estimate as broadcast side information (None round 0)."""
    if not temporal or state.prev_mean is None:
        return None
    return state.prev_mean


def measure_rho(pipe, key, payloads, ids) -> float | None:
    """One group's rho = R/(n-1) measurement from this round's payloads.

    Reconstructs each participant's unbiased contribution via the pipeline's
    self_decode and measures r_exact over the stack. Returns the estimate
    (rho, in [0, 1]) or None when the codec has no per-client reconstruction
    or n < 2. Pure measurement — the cross-round EMA is ``ema_update`` (one
    step per round, however many budget groups contributed).
    """
    pipe = as_pipeline(pipe)
    if not pipe.sparsifier.supports_self_decode:
        return None
    n = len(ids)
    if n < 2:
        return None
    id_arr = jnp.asarray(np.asarray(ids))
    recon = jax.vmap(
        lambda i, p: pipe.self_decode(key, i, p)
    )(id_arr, payloads)  # (n, C, d)
    # de-inflate the denominator by each codec's exact second-moment factor
    # E||self_decode||^2 / ||x||^2: d/k for the Rand-k / SRHT family, the
    # density-corrected (d/k)(1 + (k-1)/d + 2(nnz-1)/(nnz d)) for SparseProj's
    # with-replacement rows, 1.0 for identity/top_k. The sparsifier declares
    # it (codec.Sparsifier.self_decode_norm_inflation) — name-matching here
    # once applied the orthonormal-row d/k to sparse_proj, biasing the wavg
    # R-hat low by the density term.
    scale = pipe.sparsifier.self_decode_norm_inflation
    r_round = float(correlation.r_exact(recon)) * scale
    return float(np.clip(r_round / (n - 1.0), 0.0, 1.0))


def ema_update(state: ServerState, rho_round: float, gamma: float = 0.3) -> None:
    """Advance the cross-round tracker by exactly one EMA step."""
    state.r_ema = (
        rho_round if state.r_ema is None
        else (1.0 - gamma) * state.r_ema + gamma * rho_round
    )
    state.r_history.append(rho_round)


def admit_stale(fresh_mean, n_fresh: int, stale_mean, n_stale: int,
                stale_weight: float = 1.0):
    """Combine the fresh survivors' decode with an admitted stale group's
    (async rounds, staleness-1 aggregation — docs/DESIGN.md §9.2).

    Client-count weighting with ``stale_weight`` per stale client:

        (n_fresh * fresh + w * n_stale * stale) / (n_fresh + w * n_stale)

    At ``stale_weight=1`` this treats a one-round-late payload as a full
    participant — the right call when the drift per round is small relative
    to per-client noise (the regime the temporal machinery targets);
    down-weight toward 0 to fade admission out as drift grows.
    """
    w = stale_weight * n_stale
    return (n_fresh * fresh_mean + w * stale_mean) / (n_fresh + w)


def intra_pod_reduction(info: dict) -> float | None:
    """allgather/ownership server-side traffic ratio of a decode — the
    server-policy view of ``dist.collectives.intra_pod_reduction`` (one
    implementation; re-exported here because the FL server is where the
    ratio becomes a reporting/policy quantity)."""
    from ..dist import collectives

    return collectives.intra_pod_reduction(info)


def commit_round(state: ServerState, mean_chunks) -> None:
    """Store the decoded mean as next round's temporal side information."""
    state.prev_mean = mean_chunks
    state.round += 1
