"""Paper-fidelity tests for the estimator family.

Validates (against the paper's own claims):
  - unbiasedness of every unbiased estimator (statistical)
  - Eq. 1   : Rand-k MSE == (1/n^2)(d/k - 1) sum ||x_i||^2
  - Thm 4.3 : Rand-Proj-Spatial(Max) MSE ~= (d/nk - 1)||x||^2 (identical vecs)
  - Thm 4.4 : Rand-Proj-Spatial(T==1) MSE == Rand-k MSE (orthogonal vecs)
  - Lemma 4.1: projection="subsample" reproduces Rand-k-Spatial exactly
  - Gram decode == paper-literal direct decode (our docs/DESIGN.md §3.3 claim)
  - App. A.1: same rotation for all clients gives no improvement
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chunking, codec, correlation, mean_estimate
from repro.core import beta as beta_lib
from repro.core.estimators import decode, encode_all

jax.config.update("jax_platform_name", "cpu")


def run_trials(spec, xs, trials=200, seed=0):
    """Return (mean_estimates (t, C, d), mse (t,))."""
    xbar = jnp.mean(xs, axis=0)

    @jax.jit
    def one(key):
        xh = mean_estimate(spec, key, xs)
        return xh, correlation.mse(xh, xbar)

    keys = jax.random.split(jax.random.key(seed), trials)
    xhs, mses = jax.lax.map(one, keys)
    return np.asarray(xhs), np.asarray(mses)


def make_clients(kind, n, d, seed=0):
    rng = np.random.default_rng(seed)
    if kind == "identical":
        x = rng.standard_normal(d)
        xs = np.tile(x, (n, 1))
    elif kind == "orthogonal":
        q, _ = np.linalg.qr(rng.standard_normal((d, n)))
        xs = q.T * np.sqrt(d)
    else:
        xs = rng.standard_normal((n, d))
    xs = xs / np.linalg.norm(xs, axis=1, keepdims=True)  # unit norm as in paper
    return jnp.asarray(xs[:, None, :], jnp.float32)  # (n, C=1, d)


UNBIASED = [
    ("rand_k", {}),
    ("rand_k_spatial", {"transform": "avg"}),
    ("rand_proj_spatial", {"transform": "avg"}),
    ("rand_proj_spatial", {"transform": "max"}),
    ("wangni", {}),
    ("induced", {}),
]


@pytest.mark.parametrize("name,kw", UNBIASED, ids=[f"{n}-{v.get('transform','')}" for n, v in UNBIASED])
def test_unbiasedness(name, kw):
    n, d, k = 8, 128, 8
    xs = make_clients("generic", n, d)
    spec = codec.build(name, k=k, d_block=d, **kw)
    xhs, _ = run_trials(spec, xs, trials=600)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    # sem-scaled tolerance: estimator std / sqrt(trials)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err < 6 * sem + 5e-3).all(), float(err.max())


def test_rand_k_mse_matches_eq1():
    n, d, k = 8, 128, 8
    xs = make_clients("generic", n, d)
    spec = codec.build("rand_k", k=k, d_block=d)
    _, mses = run_trials(spec, xs, trials=1500)
    norm_sq = float(jnp.sum(xs.astype(jnp.float32) ** 2))
    want = (1 / n**2) * (d / k - 1) * norm_sq
    got = mses.mean()
    assert abs(got - want) / want < 0.12, (got, want)


def test_thm_4_3_full_correlation():
    """Identical vectors, T=id ('max'): MSE ~= (d/(nk) - 1) ||x||^2."""
    n, d, k = 8, 128, 8
    xs = make_clients("identical", n, d)
    spec = codec.build("rand_proj_spatial", k=k, d_block=d, transform="max")
    _, mses = run_trials(spec, xs, trials=400)
    norm_sq = float(jnp.sum(xs[0].astype(jnp.float32) ** 2))
    want = (d / (n * k) - 1) * norm_sq
    got = mses.mean()
    assert abs(got - want) / want < 0.15, (got, want)
    # strictly better than Rand-k (paper App. C.2, delta << 2/3):
    # here (d/(nk)-1) / ((1/n)(d/k-1)) = 8/15, so ~1.9x better:
    rand_k_mse = (1 / n) * (d / k - 1) * norm_sq
    assert got < rand_k_mse * 0.7


def test_thm_4_4_no_correlation():
    """Orthogonal vectors, T==1 ('one'): MSE == Rand-k's Eq. 1."""
    n, d, k = 8, 128, 8
    xs = make_clients("orthogonal", n, d)
    spec = codec.build("rand_proj_spatial", k=k, d_block=d, transform="one")
    _, mses = run_trials(spec, xs, trials=1000)
    norm_sq = float(jnp.sum(xs.astype(jnp.float32) ** 2))
    want = (1 / n**2) * (d / k - 1) * norm_sq
    assert abs(mses.mean() - want) / want < 0.12, (mses.mean(), want)


def test_lemma_4_1_subsample_recovers_rand_k_spatial():
    """Rand-Proj-Spatial with E_i == Rand-k-Spatial, same key => exact match."""
    n, d, k = 6, 64, 4
    xs = make_clients("generic", n, d)
    key = jax.random.key(7)
    s_proj = codec.build(
        "rand_proj_spatial", k=k, d_block=d, transform="avg",
        projection="subsample", decode_method="direct",
    )
    s_spatial = codec.build("rand_k_spatial", k=k, d_block=d, transform="avg")
    # NOTE: identical randomness requires identical index derivation; both
    # derive rows via permutation(client_key)[:k], so payload contents match.
    a = mean_estimate(s_proj, key, xs)
    b = mean_estimate(s_spatial, key, xs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("seed", range(6))
def test_lemma_4_1_property_over_seeds(seed):
    """Property test (ISSUE 2): for ANY seed/key/data draw, Rand-Proj-Spatial
    with projection='subsample' matches Rand-k-Spatial's decode exactly —
    shared-randomness and per-chunk modes, gram and direct decode paths."""
    n, d, k = 5, 64, 4
    rng = np.random.default_rng(100 + seed)
    xs = jnp.asarray(rng.standard_normal((n, 2, d)), jnp.float32)
    key = jax.random.key(1000 + seed)
    for shared in (True, False):
        for method in ("direct", "gram"):
            s_proj = codec.build(
                "rand_proj_spatial", k=k, d_block=d, transform="avg",
                projection="subsample", decode_method=method,
                shared_randomness=shared,
            )
            s_spatial = codec.build(
                "rand_k_spatial", k=k, d_block=d, transform="avg",
                shared_randomness=shared,
            )
            a = mean_estimate(s_proj, key, xs)
            b = mean_estimate(s_spatial, key, xs)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4,
                err_msg=f"shared={shared} method={method}",
            )


def test_lemma_4_1_under_error_feedback():
    """Lemma 4.1 extends through error feedback: the subsample projection's
    (d/k) G^T z self-decode IS Rand-k's (d/k) scatter, so means AND residual
    trajectories coincide over multiple EF rounds."""
    from repro.dist import collectives

    n, d, k = 4, 64, 4
    rng = np.random.default_rng(9)
    tree = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    s_proj = codec.build(
        "rand_proj_spatial", k=k, d_block=d, transform="avg",
        projection="subsample", decode_method="direct", ef=True,
    )
    s_spatial = codec.build("rand_k_spatial", k=k, d_block=d,
                              transform="avg", ef=True)
    ef_a = ef_b = jnp.zeros((n, 1, d))
    for t in range(4):
        key = jax.random.fold_in(jax.random.key(11), t)
        mean_a, _, ef_a = collectives.compressed_mean_tree(
            s_proj, key, tree, ef_chunks=ef_a
        )
        mean_b, _, ef_b = collectives.compressed_mean_tree(
            s_spatial, key, tree, ef_chunks=ef_b
        )
        np.testing.assert_allclose(
            np.asarray(mean_a["w"]), np.asarray(mean_b["w"]),
            rtol=2e-3, atol=2e-4, err_msg=f"round {t} mean",
        )
        np.testing.assert_allclose(
            np.asarray(ef_a), np.asarray(ef_b), rtol=2e-3, atol=2e-4,
            err_msg=f"round {t} residual",
        )


def test_gram_decode_equals_direct_decode():
    n, d, k = 5, 64, 4
    xs = make_clients("generic", n, d)
    key = jax.random.key(3)
    for transform in ("one", "max", "avg"):
        sg = codec.build("rand_proj_spatial", k=k, d_block=d,
                           transform=transform, decode_method="gram")
        sd = sg.replace(decode_method="direct")
        a = mean_estimate(sg, key, xs)
        b = mean_estimate(sd, key, xs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_gram_decode_equals_direct_decode_per_chunk_and_est():
    n, d, k = 5, 64, 4
    xs = jnp.asarray(np.random.default_rng(5).standard_normal((n, 3, d)), jnp.float32)
    key = jax.random.key(4)
    sg = codec.build("rand_proj_spatial", k=k, d_block=d, r_mode="est",
                       shared_randomness=False, decode_method="gram")
    sd = sg.replace(decode_method="direct")
    a = mean_estimate(sg, key, xs)
    b = mean_estimate(sd, key, xs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_varying_correlation_ordering():
    """Given R, Rand-Proj-Spatial(Opt) < Rand-k-Spatial(Opt) < Rand-k (Fig. 3).

    Paper §4.3 simulation setup: clients hold canonical base vectors; the
    number of clients sharing a vector sets R. Same round keys across
    estimators => rand_k vs rand_k_spatial is a PAIRED comparison (identical
    payloads, different decode), which separates the small gap cleanly.
    """
    n, d, k = 8, 256, 24
    base_vecs = np.eye(d)[:2]
    assign = np.array([0, 0, 0, 0, 0, 0, 1, 1])  # R = (6*5 + 2*1)/8 = 4.0
    xs = jnp.asarray(base_vecs[assign][:, None, :], jnp.float32)
    r = float(correlation.r_exact(xs))
    assert r == pytest.approx(4.0)
    res = {}
    for name, tf in [("rand_k", "one"), ("rand_k_spatial", "opt"), ("rand_proj_spatial", "opt")]:
        spec = codec.build(name, k=k, d_block=d, transform=tf, r_value=r)
        _, res[name] = run_trials(spec, xs, trials=600, seed=2)
    paired = res["rand_k"] - res["rand_k_spatial"]
    sem = paired.std() / np.sqrt(len(paired))
    assert paired.mean() > 1.5 * sem, (paired.mean(), sem)  # spatial beats rand_k
    assert res["rand_proj_spatial"].mean() < res["rand_k_spatial"].mean() * 0.99


def test_same_rotation_no_gain_appendix_a1():
    """Pre-rotating every client by the SAME orthonormal G leaves Rand-k MSE unchanged."""
    n, d, k = 8, 128, 8
    xs = make_clients("generic", n, d, seed=3)
    from repro.kernels import ref as kref

    h = kref.hadamard_matrix(d) / np.sqrt(d)  # orthonormal rotation
    dsigns = np.sign(np.random.default_rng(0).standard_normal(d))
    g = h * dsigns[None, :]
    xs_rot = jnp.einsum("ncd,ed->nce", xs, jnp.asarray(g, jnp.float32))
    spec = codec.build("rand_k", k=k, d_block=d)
    _, m_plain = run_trials(spec, xs, trials=800)
    _, m_rot = run_trials(spec, xs_rot, trials=800, seed=1)
    # rotation is an isometry; decoded-back MSE identical in distribution
    assert abs(m_plain.mean() - m_rot.mean()) / m_plain.mean() < 0.1


def test_beta_closed_forms():
    n, k, d = 8, 8, 128
    # rho=0 -> d/k exactly (tr(S) = nk)
    assert beta_lib.srht_beta(n, k, d, 0.0) == pytest.approx(d / k)
    # rho=1 -> d/k * nk/E[rank] ~= d/k (full rank w.h.p.); the theorem's
    # effective d/(nk) scale is beta/n with our x_hat = (beta/n)(...) convention.
    assert beta_lib.srht_beta(n, k, d, 1.0) == pytest.approx(d / k, rel=0.02)
    # rand-k-spatial closed form at rho=1: beta = n/(1-(1-k/d)^n)
    got = float(beta_lib.rand_k_spatial_beta(n, k, d, 1.0))
    want = n / (1 - (1 - k / d) ** n)
    assert got == pytest.approx(want, rel=1e-4)
    # rho=0 -> d/k (recovers Rand-k scaling)
    assert float(beta_lib.rand_k_spatial_beta(n, k, d, 0.0)) == pytest.approx(d / k, rel=1e-5)


def test_rank_s_full_whp():
    """Paper App. C.3: rank(S) == nk with high probability."""
    n, k, d = 8, 8, 128
    bank = beta_lib.srht_eig_bank(n, k, d, trials=64, seed=1)
    ranks = (bank > 1e-4).sum(axis=1)
    assert (ranks == n * k).mean() > 0.95


def test_chunking_roundtrip():
    rng = np.random.default_rng(0)
    for d_flat in (5, 64, 100, 1030):
        x = jnp.asarray(rng.standard_normal(d_flat), jnp.float32)
        xc = chunking.chunk(x, 64)
        np.testing.assert_array_equal(np.asarray(chunking.unchunk(xc, d_flat)), np.asarray(x))


def test_tree_chunk_restore():
    tree = {"a": jnp.arange(7, dtype=jnp.float32), "b": (jnp.ones((3, 5)),)}
    xc, restore = chunking.tree_chunk(tree, 16)
    back = restore(xc)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(7, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(back["b"][0]), np.ones((3, 5), np.float32))
