"""Async execution layer: overlapped collectives, staleness-1 FL rounds.

Three claims are pinned here:

1. **Overlap parity** — ``overlap=True`` (double-buffered chunk streaming)
   is BIT-identical to the synchronous path: through both dist entry points
   (with participants and error feedback) and through ``fl.rounds`` on all
   three backends. Non-streamable pipelines are rejected, never silently
   degraded.
2. **Staleness-1 admission** — with ``dropout=0`` the async driver equals
   the sync one exactly; with stragglers, admitting their late payloads
   (a) improves population MSE vs dropping them and (b) costs exactly the
   admitted payloads' declared bytes (ledger identity).
3. **Staleness metadata** — ``codec.with_staleness`` tags a payload without
   touching arrays or wire bytes, so the ledger-honesty check and the
   decode are unchanged for stale payloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

D = 128
K = 16


def _tree(np_rng, n=6):
    return {
        "w": jnp.asarray(np_rng.standard_normal((n, 40, 20)), jnp.float32),
        "b": jnp.asarray(np_rng.standard_normal((n, 33)), jnp.float32),
    }


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


STREAMABLE = [
    codec.RandK(k=K, d_block=D),
    codec.RandKSpatial(k=K, d_block=D, transform="avg"),
    codec.RandProjSpatial(k=K, d_block=D, transform="avg"),
    codec.TopK(k=K, d_block=D),
    codec.Identity(d_block=D),
    codec.Pipeline([codec.RandProjSpatial(k=K, d_block=D), codec.Bf16Quant()]),
    codec.Pipeline([codec.RandK(k=K, d_block=D), codec.ErrorFeedback()]),
]


@pytest.mark.parametrize("spec", STREAMABLE, ids=lambda s: codec.as_pipeline(s).describe())
def test_overlap_bitwise_parity_gspmd(spec, rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(spec)
    m0, i0, e0 = collectives.compressed_mean_tree(pipe, rng_key, tree)
    m1, i1, e1 = collectives.compressed_mean_tree(pipe, rng_key, tree,
                                                  overlap=True)
    _assert_trees_equal(m0, m1)
    assert i0 == i1
    if e0 is not None:
        np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


def test_overlap_parity_with_participants_and_tile(rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    part = [0, 2, 5]
    m0, i0, _ = collectives.compressed_mean_tree(
        pipe, rng_key, tree, participants=part)
    for tile in (1, 3):
        m1, i1, _ = collectives.compressed_mean_tree(
            pipe, rng_key, tree, participants=part, overlap=True,
            overlap_tile=tile)
        _assert_trees_equal(m0, m1)
        assert i0 == i1


def test_overlap_parity_shardmap(rng_key, np_rng):
    tree = _tree(np_rng)
    mesh = jax.make_mesh((jax.device_count(),), ("pod",))
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    m0, i0, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh)
    m1, i1, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh, overlap=True)
    _assert_trees_equal(m0, m1)
    assert i0 == i1


def test_overlap_edge_tiles_ragged_and_oversized(rng_key, np_rng):
    """Edge-tile coverage: the 7-chunk grid under a tile that does NOT
    divide it (ragged final tile), a tile larger than the whole grid, and a
    tile equal to it — all bit-identical to the sync decode."""
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    m0, i0, _ = collectives.compressed_mean_tree(pipe, rng_key, tree)
    n_chunks = i0["n_chunks"]
    assert n_chunks == 7  # the fixture's d_flat=833 over d_block=128
    for tile in (2, 4, 6, n_chunks, n_chunks + 5, 64):
        m1, i1, _ = collectives.compressed_mean_tree(
            pipe, rng_key, tree, overlap=True, overlap_tile=tile)
        _assert_trees_equal(m0, m1)
        assert i0 == i1
    # tile geometry itself: ragged final tile and single oversized tile
    assert collectives.stream_tiles(7, 4) == [(0, 4), (4, 7)]
    assert collectives.stream_tiles(7, 64) == [(0, 7)]
    with pytest.raises(ValueError, match="overlap_tile"):
        collectives.stream_tiles(7, 0)


def test_overlap_edge_tiles_under_ownership(rng_key, np_rng):
    """Ragged tiles x ragged ownership: tiles are owner-local (never span an
    owner boundary) and still reproduce the sync decode bit-for-bit,
    including with error feedback riding along."""
    from repro.dist.sharding import chunk_ownership

    tree = _tree(np_rng)
    plan = chunk_ownership(7, 3)  # slices (0,3) (3,6) (6,7): ragged tail
    assert collectives.stream_tiles(7, 2, plan) == [
        (0, 2), (2, 3), (3, 5), (5, 6), (6, 7)]
    assert collectives.stream_tiles(7, 64, plan) == [(0, 3), (3, 6), (6, 7)]
    for spec in (codec.RandProjSpatial(k=K, d_block=D),
                 codec.Pipeline([codec.RandK(k=K, d_block=D),
                                 codec.ErrorFeedback()])):
        pipe = codec.as_pipeline(spec)
        m0, _, e0 = collectives.compressed_mean_tree(pipe, rng_key, tree)
        for tile in (2, 3, 64):
            m1, _, e1 = collectives.compressed_mean_tree(
                pipe, rng_key, tree, ownership=plan, overlap=True,
                overlap_tile=tile)
            _assert_trees_equal(m0, m1)
            if e0 is not None:
                np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


NON_STREAMABLE = [
    codec.Pipeline([codec.RandK(k=K, d_block=D), codec.Int8Quant()]),
    codec.RandK(k=K, d_block=D, shared_randomness=False),
    codec.Wangni(k=K, d_block=D),
    codec.Induced(k=K, d_block=D),
    codec.SparseProj(k=K, d_block=D, shared_randomness=False),
]


@pytest.mark.parametrize("spec", NON_STREAMABLE,
                         ids=lambda s: codec.as_pipeline(s).describe())
def test_overlap_rejects_non_streamable(spec, rng_key, np_rng):
    assert not codec.as_pipeline(spec).chunk_streamable
    with pytest.raises(ValueError, match="chunk-streamable"):
        collectives.compressed_mean_tree(spec, rng_key, _tree(np_rng),
                                         overlap=True)


@pytest.mark.parametrize("spec,offender", [
    (codec.Pipeline([codec.RandK(k=K, d_block=D), codec.Int8Quant()]),
     "Int8Quant"),
    (codec.RandK(k=K, d_block=D, shared_randomness=False), "RandK"),
    (codec.Wangni(k=K, d_block=D), "Wangni"),
    (codec.Induced(k=K, d_block=D), "Induced"),
    (codec.SparseProj(k=K, d_block=D, shared_randomness=False), "SparseProj"),
])
def test_check_streamable_names_offending_stage(spec, offender):
    """The rejection must NAME the stage class that breaks streamability and
    say why, not just reject generically."""
    pipe = codec.as_pipeline(spec)
    with pytest.raises(ValueError) as ei:
        collectives.check_streamable(pipe)
    msg = str(ei.value)
    assert offender in msg, msg
    assert "overlap=False" in msg  # tells the caller the way out
    if offender == "Int8Quant":
        assert "rounding noise" in msg
    else:
        assert "position" in msg


@pytest.mark.parametrize("backend", ["local", "gspmd", "shard_map"])
def test_overlap_parity_through_rounds(backend):
    """The satellite acceptance: overlap=True is bit-identical to the sync
    decode on all three fl backends (MSE and ledger, whole trajectory)."""
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=K, d_block=D, transform="avg")
    cohort = Cohort(n_clients=8, dropout=0.2)
    mesh = None if backend == "local" else jax.make_mesh(
        (jax.device_count(),), ("pod",))
    base = dict(n_rounds=4, backend=backend, mesh=mesh)
    _, h0 = run_rounds(task, pipe, cohort, RoundConfig(**base))
    _, h1 = run_rounds(task, pipe, cohort, RoundConfig(**base, overlap=True))
    assert h0.mse == h1.mse
    assert h0.bytes == h1.bytes


def test_overlap_requires_stateless_pipeline():
    task = get_task("dme", n_clients=4, d=D, rho=0.9)
    stateful = codec.Pipeline([codec.RandK(k=K, d_block=D),
                               codec.ErrorFeedback()])
    with pytest.raises(ValueError, match="stateless"):
        run_rounds(task, stateful, cfg=RoundConfig(n_rounds=1, overlap=True))


# ---------------------------------------------------------------- async rounds


def test_async_equals_sync_without_stragglers():
    """dropout=0: the stale buffer never fills, so the async driver's whole
    History matches the sync driver's exactly."""
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=K, d_block=D, transform="avg")
    _, h_sync = run_rounds(task, pipe, cfg=RoundConfig(n_rounds=5))
    _, h_async = run_rounds(task, pipe,
                            cfg=RoundConfig(n_rounds=5, async_rounds=True))
    assert h_sync.mse == h_async.mse
    assert h_sync.mse_pop == h_async.mse_pop
    assert h_sync.bytes == h_async.bytes
    assert sum(h_async.n_stale) == 0


def test_async_ledger_identity_and_staleness0_ablation():
    """Every late ARRIVAL is ledgered at its declared bytes (admitted into
    the decode or superseded by a fresh report — it crossed the wire either
    way), and staleness=0 (async scheduling, no admission) decodes
    identically to sync — the byte-ledger parity of the acceptance
    criteria."""
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=K, d_block=D, transform="avg")
    cohort = Cohort(n_clients=8, dropout=0.3)
    _, h_sync = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=10))
    _, h_async = run_rounds(task, pipe, cohort,
                            RoundConfig(n_rounds=10, async_rounds=True))
    _, h_drop = run_rounds(
        task, pipe, cohort,
        RoundConfig(n_rounds=10, async_rounds=True, staleness=0))
    assert sum(h_async.n_stale) > 0
    assert h_async.total_bytes == h_sync.total_bytes + h_async.total_stale_bytes
    per_round = [s + extra for s, extra in zip(h_sync.bytes,
                                               h_async.stale_bytes)]
    assert h_async.bytes == per_round
    assert h_drop.mse == h_sync.mse  # no admission => sync decode exactly


def test_straggler_admission_improves_population_mse():
    """The tentpole claim: a late payload admitted at staleness 1 beats
    dropping it — population MSE (vs ALL clients' current mean) improves on
    a slowly-drifting correlated task."""
    task = get_task("drift", n_clients=8, d=256, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=26, d_block=256, transform="avg")
    cohort = Cohort(n_clients=8, dropout=0.3)
    _, h_sync = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=25))
    _, h_async = run_rounds(task, pipe, cohort,
                            RoundConfig(n_rounds=25, async_rounds=True))
    assert sum(h_async.n_stale) > 0
    assert np.mean(h_async.mse_pop) < np.mean(h_sync.mse_pop)


def test_async_composes_with_per_client_temporal():
    """Stragglers' temporal memories advance when they (late-)encode, and
    the stale decode adds back the snapshot they actually encoded against."""
    task = get_task("drift", n_clients=6, d=D, rho=0.95, omega=0.02,
                    client_bias=0.5)
    pipe = codec.Pipeline([codec.RandK(k=K, d_block=D), codec.Temporal()])
    cohort = Cohort(n_clients=6, dropout=0.3)
    _, hist = run_rounds(task, pipe, cohort,
                         RoundConfig(n_rounds=8, async_rounds=True))
    assert sum(hist.n_stale) > 0
    assert hist.client_state is not None
    assert np.isfinite(hist.mse_pop).all()


def test_async_rejects_error_feedback_and_deep_staleness():
    task = get_task("dme", n_clients=4, d=D, rho=0.9)
    pipe_ef = codec.Pipeline([codec.RandK(k=K, d_block=D),
                              codec.ErrorFeedback()])
    with pytest.raises(ValueError, match="[Ee]rror feedback"):
        run_rounds(task, pipe_ef, cfg=RoundConfig(n_rounds=1,
                                                  async_rounds=True))
    pipe = codec.RandK(k=K, d_block=D)
    with pytest.raises(ValueError, match="staleness"):
        run_rounds(task, pipe, cfg=RoundConfig(n_rounds=1, async_rounds=True,
                                               staleness=2))


# ---------------------------------------------------------- staleness metadata


def test_with_staleness_pure_metadata(rng_key):
    """The staleness tag changes neither arrays nor the declared ledger:
    stale payloads pass the same honesty check and decode to the same
    numbers (it is the decode's round KEY that differs for a stale payload,
    never its bytes)."""
    pipe = codec.as_pipeline(
        codec.Pipeline([codec.RandProjSpatial(k=K, d_block=D),
                        codec.Bf16Quant()]))
    x = jax.random.normal(jax.random.fold_in(rng_key, 7), (4, D))
    payload = pipe.encode_payload(rng_key, 0, x)
    assert payload.meta.staleness == 0
    stale = codec.with_staleness(payload, 1)
    assert stale.meta.staleness == 1
    assert payload.meta.staleness == 0  # original untouched
    assert codec.check_against_schema(stale) == []
    assert stale.nbytes == payload.nbytes
    assert stale.meta.declared_nbytes == payload.meta.declared_nbytes
    np.testing.assert_array_equal(
        np.asarray(pipe.self_decode(rng_key, 0, stale)),
        np.asarray(pipe.self_decode(rng_key, 0, payload)))

    with pytest.raises(ValueError, match="staleness"):
        codec.with_staleness(payload, -1)
    with pytest.raises(TypeError):
        codec.with_staleness({"vals": x}, 1)


def test_stale_stacked_payload_ledger(rng_key):
    """Ledger honesty extends to stale STACKED payloads: per-client bytes
    read off the schema are unchanged by the tag (what fl.rounds charges an
    admitted payload)."""
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D))
    xs = jax.random.normal(rng_key, (5, 3, D))
    payloads, _ = pipe.encode_all(rng_key, xs)
    stale = codec.with_staleness(payloads, 1)
    assert stale.per_client_nbytes() == payloads.per_client_nbytes()
    assert stale.per_client_nbytes() == pipe.payload_nbytes(3)
    np.testing.assert_array_equal(
        np.asarray(pipe.decode_payload(rng_key, stale, 5)),
        np.asarray(pipe.decode_payload(rng_key, payloads, 5)))
