"""Shared test config: CPU-only JAX, fixed seeding helpers, `slow` marker.

The main test process must stay on ONE device (the mesh tests compile on
placeholder devices inside subprocesses that set their own XLA_FLAGS), so no
device-count flags are set here. Quick local runs: `-m "not slow"` skips the
subprocess lower+compile tests.
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture
def rng_key():
    """Fixed jax PRNG round key; fold_in per-case for independent draws."""
    return jax.random.key(0)


@pytest.fixture
def np_rng():
    """Fixed numpy Generator for test-data construction."""
    return np.random.default_rng(0)
