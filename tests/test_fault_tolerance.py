"""Fault-tolerance / substrate tests: checkpoint roundtrip, crash-restore,
elastic client resize, straggler re-normalisation, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import codec, mean_estimate
from repro.core import beta as beta_lib
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import AdamW
from repro.train import checkpoint as ckpt
from repro.train import make_train_step
from repro.train.supervisor import FaultPlan, Supervisor

jax.config.update("jax_platform_name", "cpu")

CFG = configs.reduce_for_smoke(configs.get_config("mamba2-130m"))
OPT = AdamW(lr=1e-2, warmup_steps=5)


def _mk_supervisor(tmp, n_clients=2, spec=None):
    spec = spec or codec.build("rand_proj_spatial", k=16, d_block=256)

    def make_step(n):
        return jax.jit(make_train_step(CFG, OPT, dme_spec=spec))

    def make_data(n):
        data = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=32, batch=2, n_clients=n)
        return data.batch_at

    def init_state():
        params = init_params(CFG, jax.random.key(0))
        return params, {"opt": OPT.init(params)}

    return Supervisor(
        make_step=make_step, make_data=make_data, init_state=init_state,
        ckpt_dir=str(tmp), n_clients=n_clients, ckpt_every=5, max_restarts=5,
    )


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 7), jnp.bfloat16), "d": jnp.zeros((), jnp.int32)}}
    ckpt.save(str(tmp_path), 3, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_keep_n_and_crash_safety(tmp_path):
    tree = {"x": jnp.ones(4)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.steps(str(tmp_path)) == [4, 5]
    # a partial tmp dir must be ignored and cleaned
    os.makedirs(tmp_path / "step_000099.tmp_dead", exist_ok=True)
    ckpt.save(str(tmp_path), 6, tree, keep=2)
    assert 99 not in ckpt.steps(str(tmp_path))
    assert not any(".tmp_" in n for n in os.listdir(tmp_path))


def test_supervisor_recovers_from_injected_failures(tmp_path):
    sup = _mk_supervisor(tmp_path / "ck")
    plan = FaultPlan(fail_at_steps=(7, 12))
    params, state, hist = sup.run(16, fault_plan=plan, log_every=1, log_fn=lambda *_: None)
    assert int(state["opt"]["step"]) >= 14  # made it to the end through 2 failures
    assert ckpt.latest_step(str(tmp_path / "ck")) == 15


def test_supervisor_resume_matches_uninterrupted(tmp_path):
    """Crash-restore must reproduce the uninterrupted trajectory exactly
    (pure-function-of-step data + checkpointed state)."""
    a = _mk_supervisor(tmp_path / "a")
    p_a, s_a, _ = a.run(11, log_fn=lambda *_: None)
    b = _mk_supervisor(tmp_path / "b")
    p_b, s_b, _ = b.run(11, fault_plan=FaultPlan(fail_at_steps=(8,)), log_fn=lambda *_: None)
    for x, y in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6)


def test_elastic_resize(tmp_path):
    sup = _mk_supervisor(tmp_path / "ck", n_clients=4)
    plan = FaultPlan(resize_at={6: 2})
    params, state, _ = sup.run(10, fault_plan=plan, log_fn=lambda *_: None)
    assert sup.n_clients == 2
    assert int(state["opt"]["step"]) == 10


def test_straggler_drop_keeps_unbiasedness():
    """Dropping a straggler = decoding with n_eff; estimator stays unbiased."""
    n, d, k = 6, 128, 8
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n, 1, d)), jnp.float32)
    spec = codec.build("rand_proj_spatial", k=k, d_block=d, transform="avg")
    # survivors: first 5 clients; mean target is the survivors' mean
    survivors = xs[:5]
    xbar = np.asarray(jnp.mean(survivors, axis=0))

    @jax.jit
    def one(key):
        return mean_estimate(spec, key, survivors)

    keys = jax.random.split(jax.random.key(1), 400)
    xh = np.asarray(jax.lax.map(one, keys))
    sem = xh.std(0) / np.sqrt(len(xh)) + 1e-4
    assert (np.abs(xh.mean(0) - xbar) < 6 * sem + 5e-3).all()
    # effective re-normalisation beta/n differs between n=6 and n_eff=5
    b6 = beta_lib.srht_beta(6, k, d, 1.0) / 6
    b5 = beta_lib.srht_beta(5, k, d, 1.0) / 5
    assert b6 != pytest.approx(b5)


def test_fl_straggler_renormalizes_by_actual_participants():
    """ISSUE 2 bugcheck: when a sampled client drops, the decoded mean must
    renormalize by the clients that actually reported — NOT the sampled
    count. Wired through fl.rounds with the identity codec, whose decode is
    exact: any 1/n_sampled normalisation would show up as a deterministic
    shrink of the mean."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    n, d = 8, 128
    task = get_task("dme", n_clients=n, d=d, rho=0.6)
    cohort = Cohort(n_clients=n, participation=1.0, dropout=0.4)
    spec = codec.build("identity", d_block=d)
    _, hist = run_rounds(task, spec, cohort, RoundConfig(n_rounds=8))
    xs = np.asarray(task.aux["xs"])  # (n, d) fixed client vectors

    dropped_any = False
    for t in range(8):
        part = cohort.sample_round(0, t)  # same deterministic draw the driver saw
        assert hist.n_survivors[t] == len(part.survivors)
        true = xs[part.survivors].mean(0)
        # correct decode: exact survivors' mean => recorded mse ~ 0
        assert hist.mse[t] < 1e-9
        if len(part.survivors) < part.n_sampled:
            dropped_any = True
            # the buggy normalisation (sum / n_sampled) is measurably wrong
            buggy = xs[part.survivors].sum(0) / part.n_sampled
            assert float(np.sum((buggy - true) ** 2)) > 1e-3
    assert dropped_any, "dropout=0.4 over 8 rounds never dropped a client"


def test_fl_straggler_renormalizes_with_sparsifying_codec():
    """Same bugcheck through a key-rederiving codec: rand_k with k == d_block
    is an exact (permutation-complete) encode, so the decode over survivors
    must reproduce their exact mean — which only happens when both the
    client_ids and the 1/n_eff normalisation are the survivors'."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    n, d = 6, 64
    task = get_task("dme", n_clients=n, d=d, rho=0.5)
    cohort = Cohort(n_clients=n, dropout=0.35)
    spec = codec.build("rand_k", k=d, d_block=d)
    _, hist = run_rounds(task, spec, cohort, RoundConfig(n_rounds=6))
    assert any(s < m for s, m in zip(hist.n_survivors, hist.n_sampled))
    assert max(hist.mse) < 1e-8


def test_data_pipeline_determinism_and_noniid():
    data = SyntheticLM(vocab_size=128, seq_len=16, batch=2, n_clients=3, seed=4)
    b1, b2 = data.batch_at(10), data.batch_at(10)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))
    b3 = data.batch_at(11)
    assert not np.array_equal(np.asarray(b1["inputs"]), np.asarray(b3["inputs"]))
    # non-IID skew shifts client marginals apart
    skew = SyntheticLM(vocab_size=128, seq_len=256, batch=2, n_clients=2, seed=4, non_iid=1.0)
    b = skew.batch_at(0)
    h0 = np.bincount(np.asarray(b["inputs"][0]).ravel(), minlength=128)
    h1 = np.bincount(np.asarray(b["inputs"][1]).ravel(), minlength=128)
    overlap = np.minimum(h0, h1).sum() / h0.sum()
    iid = SyntheticLM(vocab_size=128, seq_len=256, batch=2, n_clients=2, seed=4)
    bi = iid.batch_at(0)
    g0 = np.bincount(np.asarray(bi["inputs"][0]).ravel(), minlength=128)
    g1 = np.bincount(np.asarray(bi["inputs"][1]).ravel(), minlength=128)
    overlap_iid = np.minimum(g0, g1).sum() / g0.sum()
    assert overlap < overlap_iid
