"""The observability layer (ISSUE 7): zero-overhead-when-disabled metrics
registry, jit/tracer safety, deterministic counters, and the round-timeline
tracer's byte-ledger parity with History.

The load-bearing contract is the DISABLED case: with obs off (the default),
instrumented code must be bitwise-identical to uninstrumented code on every
backend — observability must never change the math it observes.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

jax.config.update("jax_platform_name", "cpu")

D = 64


@pytest.fixture(autouse=True)
def obs_clean():
    """Every test starts and ends disabled+empty, with no tracer installed:
    obs state is process-global, so leakage would couple tests."""
    obs.disable()
    obs.reset()
    obs.uninstall_tracer()
    yield
    obs.disable()
    obs.reset()
    obs.uninstall_tracer()


def _pipe():
    return codec.Pipeline([codec.RandProjSpatial(k=8, d_block=D, transform="avg")])


def _run(backend="local", **cfg_kw):
    task = get_task("drift", n_clients=6, d=2 * D)
    cfg = RoundConfig(n_rounds=4, backend=backend, **cfg_kw)
    return run_rounds(task, _pipe(), Cohort(n_clients=6), cfg)


# ------------------------------------------------------------ registry basics


def test_disabled_recording_is_a_noop():
    obs.count("t", "c")
    obs.gauge("t", "g", 3.0)
    obs.observe("t", "h", 1.0)
    obs.marker("t", "m")
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["gauges"] == {}
    assert snap["histograms"] == {} and snap["tracer_drops"] == 0
    # the disabled span is one shared object (no per-call allocation) that
    # still yields an annotatable dict
    s1, s2 = obs.span("t", "s"), obs.span("t", "s")
    assert s1 is s2
    with s1 as ann:
        ann["late"] = 1  # must not raise


def test_enabled_recording_and_keys():
    obs.enable()
    obs.count("codec", "decode.calls", sparsifier="rand_k")
    obs.count("codec", "decode.calls", sparsifier="rand_k")
    obs.gauge("bench", "x.compile_us", 12.5)
    obs.observe("fl", "round.duration_us", 3.0)
    with obs.span("fl", "step") as ann:
        ann["note"] = "hi"
    snap = obs.snapshot()
    assert snap["counters"]["codec/decode.calls{sparsifier=rand_k}"] == 2
    assert snap["gauges"]["bench/x.compile_us"] == 12.5
    assert snap["counters"]["fl/step.calls"] == 1
    assert snap["histograms"]["fl/step.duration_us"]["count"] == 1
    obs.reset()
    assert obs.snapshot()["counters"] == {}


def test_registry_is_tracer_safe_under_jit():
    """Recording a traced value inside jit must not leak the tracer, raise,
    or force concretization: the sample is dropped and counted."""
    obs.enable()

    @jax.jit
    def f(x):
        obs.count("t", "dynamic", x)        # tracer -> dropped
        obs.gauge("t", "dyn_gauge", x * 2)  # tracer -> dropped
        obs.count("t", "static", 1)         # python int -> records at trace time
        with obs.span("t", "blk", dyn=x, static_lbl="s") as ann:
            ann["also_dyn"] = x + 1
            y = x * 3.0
        return y

    out = f(jnp.float32(2.0))
    assert float(out) == 6.0
    snap = obs.snapshot()
    assert "t/dynamic" not in snap["counters"]
    assert "t/dyn_gauge" not in snap["gauges"]
    assert snap["counters"]["t/static"] == 1  # once: recorded at trace time
    assert snap["tracer_drops"] >= 3
    # second call hits the jit cache: no re-trace, counters unchanged
    f(jnp.float32(5.0))
    assert obs.snapshot()["counters"]["t/static"] == 1


def test_counters_deterministic_across_runs():
    """Same seed + same config => identical counter snapshots (histograms
    hold wall-clock durations and are exempt by contract)."""
    snaps = []
    for _ in range(2):
        obs.reset()
        obs.enable()
        _run()
        snaps.append(obs.snapshot()["counters"])
        obs.disable()
    assert snaps[0] == snaps[1]
    assert any(k.startswith("fl/client_encode") for k in snaps[0])
    assert any(k.startswith("codec/decode") for k in snaps[0])


# ------------------------------------------- disabled-mode bitwise identity


@pytest.mark.parametrize("backend", ["local", "gspmd", "shard_map"])
def test_disabled_run_bitwise_identical(backend):
    """The acceptance gate: enabling obs (with a tracer installed) and
    running fully disabled produce byte-for-byte identical History metrics —
    instrumentation never perturbs the math."""
    kw = {} if backend == "local" else dict(
        mesh=jax.make_mesh((jax.device_count(),), ("pod",)))

    _, h_off = _run(backend=backend, **kw)

    obs.enable()
    obs.install_tracer(obs.Tracer())
    _, h_on = _run(backend=backend, **kw)
    obs.uninstall_tracer()
    obs.disable()

    for key in ("mse", "mse_pop", "metric", "bytes", "n_survivors"):
        a, b = getattr(h_off, key), getattr(h_on, key)
        np.testing.assert_array_equal(np.asarray(a, dtype=np.float64),
                                      np.asarray(b, dtype=np.float64),
                                      err_msg=f"History.{key} differs on {backend}")


# --------------------------------------------------- tracer + ledger parity


def _spans(tracer):
    return [e for e in tracer.events if e["ph"] == "X"]


def _tracks(tracer):
    names = {e["tid"]: e["args"]["name"] for e in tracer.events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    out = {}
    for e in _spans(tracer):
        out.setdefault(names[e["tid"]], []).append(e)
    return out


def test_trace_covers_every_phase_and_bytes_match_ledger():
    obs.enable()
    tracer = obs.install_tracer(obs.Tracer())
    _, hist = _run()
    obs.uninstall_tracer()

    tracks = _tracks(tracer)
    assert set(obs.PHASES) <= set(tracks), set(obs.PHASES) - set(tracks)
    assert len(tracks["round"]) == 4
    for phase in obs.PHASES:
        rounds_seen = {e["args"]["round"] for e in tracks[phase]}
        assert rounds_seen == {0, 1, 2, 3}, (phase, rounds_seen)

    # THE invariant: trace byte annotations sum exactly to the ledger, and
    # ride only on the wire-crossing tracks
    traced = sum(e["args"]["bytes"] for e in _spans(tracer)
                 if "bytes" in e["args"])
    assert int(traced) == hist.total_bytes == int(np.sum(hist.bytes))
    for track, evs in tracks.items():
        if track in ("client_encode", "stale_admission"):
            continue
        assert not any("bytes" in e["args"] for e in evs), track


def test_trace_json_is_chrome_trace_format(tmp_path):
    obs.enable()
    tracer = obs.install_tracer(obs.Tracer())
    _, hist = _run()
    obs.uninstall_tracer()
    tracer.set_meta("n_rounds", 4)
    tracer.set_meta("ledger_total_bytes", hist.total_bytes)
    path = tmp_path / "trace.json"
    tracer.write(str(path))

    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert all(e["ph"] in ("X", "M", "C") for e in doc["traceEvents"])
    assert doc["metadata"]["ledger_total_bytes"] == hist.total_bytes

    # the CI gate passes on it
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tools"))
    try:
        import trace_report
        assert trace_report.report(doc) == []
    finally:
        sys.path.pop(0)


def test_history_round_records():
    _, hist = _run()
    recs = hist.round_records()
    assert len(recs) == 4 and recs[0]["round"] == 0
    assert recs[2]["bytes"] == hist.bytes[2]
    assert recs[3]["mse"] == hist.mse[3]


# ------------------------------------------------------------ kernel telemetry


def test_kernel_dispatch_telemetry():
    obs.enable()
    from repro.kernels import ops

    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, D)), jnp.float32)
    ops.fwht(x, use_pallas=False)
    snap = obs.snapshot()
    keys = [k for k in snap["counters"] if k.startswith("kernels/dispatch")]
    assert keys, snap["counters"]
    assert any("op=fwht" in k for k in keys)


def test_cg_iteration_telemetry_outside_jit():
    obs.enable()
    pipe = codec.Pipeline(
        [codec.RandProjSpatial(k=8, d_block=D, transform="avg",
                               decode_method="fused")])
    xs = jnp.asarray(
        np.random.default_rng(0).standard_normal((4, 2, D)), jnp.float32)
    payloads, _ = pipe.encode_all(jax.random.key(0), xs)
    pipe.decode(jax.random.key(0), payloads, 4)  # eager: iters readable
    snap = obs.snapshot()
    assert any(k.startswith("kernels/decode_route") for k in snap["counters"])
    assert "kernels/cg_iters" in snap["histograms"]


# ------------------------------------------- --compare metrics export (CLI)


def test_compare_metrics_json_is_per_run(tmp_path, capsys):
    """--compare + --metrics-json emits ONE merged snapshot with an entry
    per compared run, each holding its OWN counters and round records — the
    schema-v1 regression was last-writer-wins on a single cumulative blob."""
    from repro.fl import run as run_cli

    path = tmp_path / "metrics.json"
    rc = run_cli.main(["--task", "dme", "--compare", "--smoke",
                       "--metrics-json", str(path)])
    assert rc in (0, None)
    data = json.loads(path.read_text())
    assert data["schema_version"] == 2
    labels = [r["estimator"] for r in data["runs"]]
    assert labels == ["rand_k", "rand_k_spatial", "rand_proj_spatial",
                      "sparse_proj"]
    assert data["run"]["estimators"] == labels
    assert data["run"]["n_rounds"] == 12  # 3 smoke rounds x 4 runs
    for entry in data["runs"]:
        assert len(entry["rounds"]) == 3
        encodes = [v for k, v in entry["metrics"]["counters"].items()
                   if "client_encode" in k]
        # each run's snapshot counts ITS 3 rounds, not a running total
        assert encodes and sum(encodes) == 3.0, entry["metrics"]["counters"]
