"""Unit tests for the HLO collective parser used by the roofline harness."""
from repro.launch.hlo_stats import collective_stats

SAMPLE = """
ENTRY %main {
  %ag = f32[4,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[256,512]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = f32[2,2]{1,0} all-to-all(%w), replica_groups={{0,1,2,3}}
  %ag2 = (f32[4]{0}, f32[8]{0}) all-reduce(%t1, %t2), replica_groups={{0,1}}
}
"""


def test_counts_and_bytes():
    st = collective_stats(SAMPLE, default_group=4)
    per = st["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-gather"]["result_bytes"] == 4 * 128 * 4
    # ring all-gather: (g-1)/g of the result crosses links
    assert per["all-gather"]["wire_bytes"] == 4 * 128 * 4 * 3 / 4
    assert per["all-reduce"]["count"] == 2
    # iota group form [16,16] -> group size 16
    ar_bytes = 256 * 512 * 2
    tuple_wire = 2 * (4 + 8) * 4 * (1 / 2)
    assert abs(per["all-reduce"]["wire_bytes"] - (2 * ar_bytes * 15 / 16 + tuple_wire)) < 1
    assert per["reduce-scatter"]["count"] == 1
    assert per["collective-permute"]["wire_bytes"] == 1024 * 4
    assert st["totals"]["count"] == 6


def test_group_size_attribution():
    st = collective_stats(SAMPLE, default_group=4)
    gs = st["per_group_size"]
    assert 2 in gs and 16 in gs and 4 in gs
    # the pod-axis bucket (g=2): reduce-scatter + tuple all-reduce
    assert gs[2]["count"] == 2
