"""Seeded randomized property sweeps (no third-party property-test dep).

Three invariant families, each swept over parametrized grids (>= 200 cases
total) with deterministic per-case seeds, and each run BOTH through the
monolithic decode and the new chunk-ownership sharded decode
(docs/DESIGN.md §10) — the ownership path must preserve every invariant:

(a) **Unbiasedness** — E[decode] ≈ true mean for every registered unbiased
    sparsifier x quantizer pipeline (top_k is biased by construction and
    pairs with ErrorFeedback instead; bf16's deterministic rounding gets a
    rounding-sized slack on top of the Monte-Carlo tolerance).
(b) **Lemma 4.1-style variance ordering** — at rho -> 1,
    MSE(rand_proj_spatial) <= MSE(rand_k_spatial) <= MSE(rand_k): the
    correlation-aware decoders strictly pay off where correlation exists.
(c) **Ledger honesty** — under RANDOM budgets and participant sets, the
    declared byte ledger equals the actual array bytes, ``bytes_sent``
    charges exactly the survivors, and the intra-pod columns are
    internally consistent.
(d) **Rho-tracker calibration** — ``fl.server.measure_rho`` on known-rho
    cohorts lands within tolerance of the true rho and NEVER overclaims,
    for every self-decodable sparsifier (sparse_proj at several densities —
    the per-codec ``self_decode_norm_inflation`` regression) x quantizer.
(e) **Entropy-coded wire honesty** — ``EntropyCode``'s declared coded size
    equals the length of the byte stream it actually emits, and the stream
    round-trips bit-exactly, per sparsifier x quantizer.
(f) **Adaptive per-chunk budgets** — the allocator conserves the total
    budget exactly, the chunk_budgets decode stays unbiased at unchanged
    wire bytes, and the composition gates reject what cannot compose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives
from repro.dist.sharding import chunk_ownership

D = 64
C = 2
N = 6
K = 8

# (name, sparsifier ctor) — the unbiased family (top_k excluded: biased)
UNBIASED_SPARSIFIERS = [
    ("rand_k", lambda: codec.RandK(k=K, d_block=D)),
    ("rand_k_spatial", lambda: codec.RandKSpatial(k=K, d_block=D,
                                                  transform="avg")),
    ("rand_proj_spatial", lambda: codec.RandProjSpatial(k=K, d_block=D,
                                                        transform="avg")),
    ("wangni", lambda: codec.Wangni(k=K, d_block=D)),
    ("induced", lambda: codec.Induced(k=K, d_block=D)),
    ("identity", lambda: codec.Identity(d_block=D)),
    ("sparse_proj", lambda: codec.SparseProj(k=K, d_block=D, s=8.0,
                                             transform="avg")),
]

QUANTIZERS = [
    ("none", None),
    ("bf16", codec.Bf16Quant),
    ("int8", codec.Int8Quant),
    ("correlated", codec.CorrelatedQuant),
]


def _pipeline(sp_ctor, q_ctor):
    stages = [sp_ctor()]
    if q_ctor is not None:
        stages.append(q_ctor())
    return codec.Pipeline(stages)


def _clients(seed, n=N, c=C, d=D, rho=None):
    """(n, c, d) client chunks; ``rho`` close to 1 => near-identical rows."""
    rng = np.random.default_rng(seed)
    if rho is None:
        xs = rng.standard_normal((n, c, d))
    else:
        base = rng.standard_normal((c, d))
        noise = rng.standard_normal((n, c, d))
        xs = rho * base[None] + np.sqrt(max(0.0, 1 - rho**2)) * noise
    xs = xs / np.linalg.norm(xs, axis=-1, keepdims=True)
    return jnp.asarray(xs, jnp.float32)


def _mc_estimates(pipe, xs, plan, trials, seed):
    """(trials, C, d) decodes under independent round keys; the decode runs
    owner-partitioned when ``plan`` is given."""
    n = xs.shape[0]

    @jax.jit
    def one(key):
        payloads, _ = pipe.encode_all(key, xs)
        if plan is None:
            return pipe.decode_payload(key, payloads, n)
        return collectives.sharded_decode(pipe, key, payloads, n, plan)

    keys = jax.random.split(jax.random.key(seed), trials)
    return np.asarray(jax.lax.map(one, keys))


# ------------------------------------------------------------ (a) unbiasedness


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("q_name,q_ctor", QUANTIZERS, ids=[q for q, _ in QUANTIZERS])
@pytest.mark.parametrize("sp_name,sp_ctor", UNBIASED_SPARSIFIERS,
                         ids=[s for s, _ in UNBIASED_SPARSIFIERS])
@pytest.mark.parametrize("seed", [0, 1])
def test_unbiasedness_sparsifier_x_quantizer(sp_name, sp_ctor, q_name, q_ctor,
                                             seed, ownership):
    """E[decode] ≈ mean for every unbiased sparsifier x quantizer pipeline
    (CorrelatedQuant's cohort-shared dither included — each client's dither
    stays marginally uniform, so unbiasedness must survive it on every
    sparsifier), monolithic AND owner-partitioned (112 cases)."""
    pipe = _pipeline(sp_ctor, q_ctor)
    xs = _clients(seed)
    plan = chunk_ownership(C, 2) if ownership else None
    xhs = _mc_estimates(pipe, xs, plan, trials=160, seed=100 + seed)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    # bf16 rounding is deterministic (not unbiased): allow its rounding size
    slack = 8e-3 if q_name == "bf16" else 5e-3
    assert (err < 6 * sem + slack).all(), (pipe.describe(), float(err.max()))


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("projection", ["srht", "subsample"])
@pytest.mark.parametrize("seed", [0, 1])
def test_unbiasedness_fused_decode_routes(projection, seed, ownership):
    """Unbiasedness survives the fused kernel decode (docs/DESIGN.md §3.5)
    through BOTH decode routes — monolithic and owner-partitioned — for the
    CG resolvent solve (srht; the ridge eps is compensated exactly by the
    recalibrated beta) and the diagonal closed form (subsample)."""
    pipe = codec.as_pipeline(codec.RandProjSpatial(
        k=K, d_block=D, transform="avg", projection=projection,
        decode_method="fused"))
    xs = _clients(seed, rho=0.9)
    plan = chunk_ownership(C, 2) if ownership else None
    xhs = _mc_estimates(pipe, xs, plan, trials=160, seed=500 + seed)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err < 6 * sem + 5e-3).all(), (projection, float(err.max()))


def test_top_k_is_biased_hence_excluded():
    """The counter-property: top_k's E[decode] != mean (that is WHY it pairs
    with ErrorFeedback and sits outside the unbiased sweep)."""
    pipe = codec.as_pipeline(codec.TopK(k=4, d_block=D))
    xs = _clients(3)
    xhs = _mc_estimates(pipe, xs, None, trials=160, seed=3)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err > 6 * sem + 5e-3).any()


# ------------------------------------------- (b) variance ordering at rho -> 1


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("k", [4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lemma_41_variance_ordering_high_rho(n, k, seed, ownership):
    """At rho -> 1 the paper's ordering holds (24 cases):

        MSE(rand_proj_spatial) <= MSE(rand_k_spatial) <= MSE(rand_k)

    and survives the owner-partitioned decode unchanged."""
    xs = _clients(seed, n=n, c=1, rho=0.995)
    plan = chunk_ownership(1, 2) if ownership else None
    xbar = np.asarray(jnp.mean(xs, axis=0))

    def mc_mse(spec):
        pipe = codec.as_pipeline(spec)
        xhs = _mc_estimates(pipe, xs, plan, trials=150, seed=200 + seed)
        return float(np.mean(np.sum((xhs - xbar[None]) ** 2, axis=(1, 2))))

    mse_rk = mc_mse(codec.RandK(k=k, d_block=D))
    mse_rks = mc_mse(codec.RandKSpatial(k=k, d_block=D, transform="avg"))
    mse_rps = mc_mse(codec.RandProjSpatial(k=k, d_block=D, transform="avg"))
    # small MC slack; the expected gaps are factors, not percents
    assert mse_rps <= mse_rks * 1.05, (mse_rps, mse_rks)
    assert mse_rks <= mse_rk * 1.05, (mse_rks, mse_rk)
    assert mse_rps < mse_rk * 0.9, (mse_rps, mse_rk)


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
def test_sparse_proj_variance_ordering_high_rho(ownership):
    """Lemma 4.1-style ordering for the cheap-encode member: at rho -> 1
    SparseProj's Gram-resolvent decode never loses to plain Rand-k at equal
    budget, and wins clearly on average across the (n, k, seed) grid —
    correlation-awareness survives the very-sparse maps."""
    plan = chunk_ownership(1, 2) if ownership else None
    ratios = []
    for n in (4, 8):
        for k in (4, 8):
            for seed in range(3):
                xs = _clients(seed, n=n, c=1, rho=0.995)
                xbar = np.asarray(jnp.mean(xs, axis=0))

                def mc_mse(spec):
                    pipe = codec.as_pipeline(spec)
                    xhs = _mc_estimates(pipe, xs, plan, trials=150,
                                        seed=200 + seed)
                    return float(np.mean(np.sum((xhs - xbar[None]) ** 2,
                                                axis=(1, 2))))

                mse_rk = mc_mse(codec.RandK(k=k, d_block=D))
                mse_sp = mc_mse(codec.SparseProj(k=k, d_block=D, s=8.0,
                                                 transform="avg"))
                # per-case: never worse than rand_k modulo MC slack
                assert mse_sp <= mse_rk * 1.05, (n, k, seed, mse_sp, mse_rk)
                ratios.append(mse_sp / mse_rk)
    # aggregate: the decode pays off, not just ties (observed mean ~0.7)
    assert np.mean(ratios) < 0.9, ratios


def test_sparse_proj_density_sweep_monotone_flops_bounded_variance():
    """Sparser maps (s up) must get STRICTLY cheaper to encode while the
    decode variance stays bounded: MSE at every density within 1.25x of the
    densest map's (observed <= 1.05x; the slack is MC noise, not physics)."""
    xs = _clients(0, c=1, rho=0.9)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    flops, mses = [], []
    for s in (1.0, 2.0, 4.0, 8.0, 16.0, 32.0):
        sp = codec.SparseProj(k=K, d_block=D, s=s, transform="avg")
        flops.append(sp.encode_flops_per_chunk())
        xhs = _mc_estimates(codec.as_pipeline(sp), xs, None, trials=200,
                            seed=11)
        mses.append(float(np.mean(np.sum((xhs - xbar[None]) ** 2,
                                         axis=(1, 2)))))
    assert all(a > b for a, b in zip(flops, flops[1:])), flops
    assert max(mses) <= mses[0] * 1.25, list(zip(flops, mses))


@pytest.mark.parametrize("backend", ["local", "gspmd", "shard_map"])
def test_sparse_proj_backend_parity(backend):
    """SparseProj through fl.rounds on all three backends: identical MSE
    trajectory and byte ledger (the estimator is backend-agnostic)."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    task = get_task("dme", n_clients=6, d=D, rho=0.9)
    pipe = codec.SparseProj(k=K, d_block=D, s=8.0, transform="avg")
    cohort = Cohort(n_clients=6, dropout=0.2)
    _, h_ref = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=3))
    if backend == "local":
        h_cmp = h_ref
    else:
        mesh = jax.make_mesh((jax.device_count(),), ("pod",))
        _, h_cmp = run_rounds(task, pipe, cohort,
                              RoundConfig(n_rounds=3, backend=backend,
                                          mesh=mesh))
    np.testing.assert_allclose(h_ref.mse, h_cmp.mse, rtol=1e-4, atol=1e-6)
    assert h_ref.bytes == h_cmp.bytes


# ------------------------------------------------------------ (c) ledger honesty


LEDGER_SPARSIFIERS = ["rand_k", "rand_k_spatial", "top_k", "wangni",
                      "induced", "identity"]


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("seed", range(60))
def test_ledger_honesty_random_budgets_participants(seed, ownership):
    """120 randomized cases: random sparsifier/quantizer/budget/participant
    draws; the declared schema must equal the actual payload bytes, the
    collectives ledger must charge exactly the survivors, and the intra-pod
    columns must be internally consistent."""
    rng = np.random.default_rng(seed)
    name = LEDGER_SPARSIFIERS[rng.integers(len(LEDGER_SPARSIFIERS))]
    d_block = int(rng.choice([32, 64, 128]))
    # wangni's fixed-capacity packing needs capacity_slots <= d_block
    k_hi = d_block // 2 if name == "wangni" else d_block
    k = int(rng.integers(1, k_hi + 1))
    q_name, q_ctor = QUANTIZERS[rng.integers(len(QUANTIZERS))]
    kw = {"transform": "avg"} if name == "rand_k_spatial" else {}
    if name == "identity":
        stages = [codec.Identity(d_block=d_block)]
    else:
        stages = [codec.SPARSIFIERS[name](k=k, d_block=d_block, **kw)]
    if q_ctor is not None:
        stages.append(q_ctor())
    pipe = codec.Pipeline(stages)

    n_total = int(rng.integers(2, 9))
    n_part = int(rng.integers(1, n_total + 1))
    if name == "rand_k_spatial" and n_part == 1:
        # the avg/opt interpolations are undefined at n=1 (rho = R/(n-1));
        # fl.server.resolve_pipeline rewrites to "one" — mirror it here
        stages[0] = stages[0].replace(transform="one")
        pipe = codec.Pipeline(stages)
    participants = np.sort(rng.choice(n_total, n_part, replace=False))
    d_flat = int(rng.integers(d_block, 4 * d_block + 1))
    tree = {"x": jnp.asarray(rng.standard_normal((n_total, d_flat)),
                             jnp.float32)}
    n_owners = int(rng.integers(2, 5)) if ownership else None

    key = jax.random.key(seed)
    _, info, _ = collectives.compressed_mean_tree(
        pipe, key, tree, participants=participants,
        ownership=n_owners,
    )

    # declared ledger == actual payload bytes for a real encode
    payload = pipe.encode_payload(key, 0, jnp.zeros((info["n_chunks"], d_block)))
    assert codec.check_against_schema(payload) == []
    assert payload.nbytes == pipe.payload_nbytes(info["n_chunks"])

    # the collectives ledger charges exactly the survivors
    assert info["n_clients"] == n_part
    assert info["n_total"] == n_total
    assert info["bytes_sent"] == n_part * pipe.payload_nbytes(info["n_chunks"])

    # intra-pod columns: the taken route's column is THE column, and the
    # standalone model reproduces the info dict exactly
    if ownership:
        assert info["n_shards"] == n_owners
        assert info["intra_pod_bytes"] == info["intra_pod_bytes_ownership"]
        model = collectives.intra_pod_traffic(
            pipe, n_part, info["n_chunks"], n_owners,
            plan=chunk_ownership(info["n_chunks"], n_owners))
        assert model == {k: info[k] for k in model}
    else:
        assert info["intra_pod_bytes"] == 0  # single logical shard


@pytest.mark.parametrize("seed", range(12))
def test_ledger_honesty_heterogeneous_budget_rounds(seed):
    """Randomized budget-group cohorts through fl.rounds: the per-round byte
    ledger equals the sum of each group's declared payload bytes, with and
    without ownership (24 cases)."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    rng = np.random.default_rng(1000 + seed)
    n_clients = int(rng.integers(4, 9))
    budgets = tuple(int(rng.choice([4, 8, 16])) for _ in range(n_clients))
    task = get_task("dme", n_clients=n_clients, d=D, rho=0.9, seed=seed)
    pipe = codec.RandK(k=8, d_block=D)
    cohort = Cohort(n_clients=n_clients, dropout=float(rng.uniform(0, 0.4)),
                    budgets=budgets)
    cfgs = [RoundConfig(n_rounds=2, seed=seed),
            RoundConfig(n_rounds=2, seed=seed, ownership=True, n_owners=2)]
    hists = [run_rounds(task, pipe, cohort, cfg)[1] for cfg in cfgs]
    for hist in hists:
        for t in range(2):
            part = cohort.sample_round(seed, t)
            want = sum(
                codec.as_pipeline(pipe.replace(k=k_g)).payload_nbytes(1)
                * len(ids_g)
                for k_g, ids_g in cohort.budget_groups(part.survivors, pipe.k)
            )
            assert hist.bytes[t] == want
    # ownership changes the server's internal routing, never the wire ledger
    assert hists[0].bytes == hists[1].bytes
    assert hists[0].mse == hists[1].mse


# --------------------------------------------- (d) rho-tracker calibration

# small d with k close to it, so SparseProj's density correction F =
# 1 + (k-1)/d + 2(nnz-1)/(nnz d) is ~1.5: the pre-fix tracker (which applied
# the orthonormal-row d/k to sparse_proj) would read ~33% low here and fail
# the tolerance below by a wide margin.
RHO_D, RHO_K, RHO_N = 32, 16, 6

RHO_SPARSIFIERS = [
    ("rand_k", lambda: codec.RandK(k=RHO_K, d_block=RHO_D)),
    ("sparse_proj_s2", lambda: codec.SparseProj(k=RHO_K, d_block=RHO_D,
                                                s=2.0, transform="avg")),
    ("sparse_proj_s8", lambda: codec.SparseProj(k=RHO_K, d_block=RHO_D,
                                                s=8.0, transform="avg")),
    ("sparse_proj_s32", lambda: codec.SparseProj(k=RHO_K, d_block=RHO_D,
                                                 s=32.0, transform="avg")),
    ("identity", lambda: codec.Identity(d_block=RHO_D)),
]


@pytest.mark.parametrize("sp_name,sp_ctor", RHO_SPARSIFIERS,
                         ids=[s for s, _ in RHO_SPARSIFIERS])
def test_rho_tracker_calibration_known_cohorts(sp_name, sp_ctor):
    """``measure_rho`` on a known-rho cohort: within tolerance of the true
    rho AND never overclaiming, for every self-decodable sparsifier
    (sparse_proj at nnz = 16, 4 and 1 per row — the per-codec
    ``self_decode_norm_inflation`` de-inflation regression) x quantizer.

    The ground truth is r_exact over the ACTUAL cohort, not the nominal
    mixing rho, so the assertion is pure estimator calibration."""
    from repro.core import correlation
    from repro.fl import server as server_lib

    xs = _clients(0, n=RHO_N, c=C, d=RHO_D, rho=0.95)
    rho_true = float(np.clip(
        float(correlation.r_exact(xs)) / (RHO_N - 1), 0.0, 1.0))
    ids = list(range(RHO_N))
    for q_name, q_ctor in QUANTIZERS:
        pipe = _pipeline(sp_ctor, q_ctor)
        ests = []
        for t in range(32):
            key = jax.random.key(1000 + t)
            payloads, _ = pipe.encode_all(key, xs)
            ests.append(server_lib.measure_rho(pipe, key, payloads, ids))
        est = float(np.mean(ests))
        # calibration: observed |diff| <= 0.04 across the grid; the pre-fix
        # sparse_proj tracker read rho/F ~ rho - 0.28 here
        assert est >= rho_true - 0.08, (sp_name, q_name, est, rho_true)
        # the documented direction: residual ratio bias is toward 0, so the
        # tracker may underclaim but must never overclaim correlation
        assert est <= rho_true + 0.02, (sp_name, q_name, est, rho_true)


@pytest.mark.parametrize("sp", [
    codec.RandK(k=RHO_K, d_block=RHO_D),
    codec.SparseProj(k=RHO_K, d_block=RHO_D, s=2.0, transform="avg"),
    codec.SparseProj(k=RHO_K, d_block=RHO_D, s=8.0, transform="avg"),
    codec.SparseProj(k=RHO_K, d_block=RHO_D, s=32.0, transform="avg"),
], ids=["rand_k", "sparse_proj_s2", "sparse_proj_s8", "sparse_proj_s32"])
def test_self_decode_norm_inflation_matches_mc(sp):
    """The declared second-moment factor IS the measured one:
    E||self_decode(x)||^2 / ||x||^2 ≈ ``self_decode_norm_inflation``.

    For sparse_proj the declared factor carries the with-replacement
    correction F = 1 + (k-1)/d + 2(nnz-1)/(nnz d); the MC estimate must sit
    on the corrected value and clearly OFF the uncorrected d/k the tracker
    used before the fix."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((C, RHO_D)), jnp.float32)
    pipe = codec.as_pipeline(sp)

    @jax.jit
    def ratio(key):
        pl = pipe.encode_payload(key, 0, x)
        rec = pipe.self_decode(key, 0, pl)
        return jnp.sum(rec**2) / jnp.sum(x**2)

    keys = jax.random.split(jax.random.key(3), 600)
    mc = float(np.mean(np.asarray(jax.lax.map(ratio, keys))))
    declared = sp.self_decode_norm_inflation
    assert abs(mc - declared) / declared < 0.08, (mc, declared)
    uncorrected = sp.d_block / sp.k
    if declared > uncorrected:  # the sparse_proj cases
        assert abs(mc - declared) < abs(mc - uncorrected), (mc, declared)


# ------------------------------------------ (e) entropy-coded wire honesty


CODED_SPARSIFIERS = ["rand_k", "rand_k_spatial", "top_k", "wangni",
                     "induced", "identity", "sparse_proj"]


@pytest.mark.parametrize("q_name,q_ctor", QUANTIZERS,
                         ids=[q for q, _ in QUANTIZERS])
@pytest.mark.parametrize("sp_name", CODED_SPARSIFIERS)
def test_entropy_coded_ledger_honesty(sp_name, q_name, q_ctor):
    """The coded-size honesty contract, per sparsifier x quantizer (28
    cases): ``coded_nbytes`` equals the LENGTH of the stream ``encode_stream``
    actually emits, the stream round-trips bit-exactly under the declared
    schema, the stacked accounting is the per-client sum, and the store
    escape bounds every integer array at raw + 1 header byte."""
    from repro.core.codec.payload import arrays_of

    kw = {"transform": "avg"} if sp_name in ("rand_k_spatial",
                                             "sparse_proj") else {}
    if sp_name == "identity":
        sp = codec.Identity(d_block=D)
    else:
        sp = codec.SPARSIFIERS[sp_name](k=K, d_block=D, **kw)
    stages = [sp] + ([q_ctor()] if q_ctor is not None else [])
    stages.append(codec.EntropyCode())
    pipe = codec.Pipeline(stages)
    code = pipe.code_stage

    xs = _clients(7)
    key = jax.random.key(42)
    payloads, _ = pipe.encode_all(key, xs)
    per_client = [pipe.encode_payload(key, i, xs[i]) for i in range(N)]

    total = 0
    for pl in per_client:
        stream = code.encode_stream(pl)
        # the declared size IS the emitted stream's length
        assert code.coded_nbytes(pl) == len(stream)
        total += len(stream)
        # and the stream round-trips bit-exactly under the declared schema
        out = code.decode_stream(stream, pl.meta.schema)
        arrays = arrays_of(pl)
        assert set(out) == set(arrays)
        for name, a in arrays.items():
            a = np.asarray(a)
            assert out[name].dtype == a.dtype and out[name].shape == a.shape
            assert np.asarray(out[name]).tobytes() == a.tobytes(), name
        # escape bound: every integer array costs at most raw + 1 header byte
        n_int = sum(np.issubdtype(np.asarray(a).dtype, np.integer)
                    for a in arrays.values())
        assert len(stream) <= pl.nbytes + n_int

    # stacked accounting == per-client sum, through both entry points
    assert code.coded_nbytes_stacked(payloads) == total
    assert codec.coded_payload_nbytes(pipe, payloads) == total
    # without a code stage the same helper ledgers the raw actual bytes
    pipe_nc = codec.Pipeline(stages[:-1])
    pl_nc, _ = pipe_nc.encode_all(key, xs)
    assert codec.coded_payload_nbytes(pipe_nc, pl_nc) == pl_nc.nbytes


def test_entropy_store_escape_paths_round_trip():
    """Incompressible arrays take the 1-byte store escape instead of growing:
    full-range int8 noise (no Gaussian model wins), full-range int32 noise
    (no Rice parameter wins) — both bounded at raw + 1 and bit-exact."""
    from repro.core.codec.entropy import _decode_array, _encode_array

    rng = np.random.default_rng(0)
    cases = [
        rng.integers(-128, 128, size=512).astype(np.int8),
        rng.integers(-2**31, 2**31, size=256, dtype=np.int64).astype(np.int32),
    ]
    for a in cases:
        data = _encode_array(a)
        assert data[0] == 255  # the _STORE escape header
        assert len(data) == a.nbytes + 1
        out, end = _decode_array(data, 0, a.shape, a.dtype)
        assert end == len(data)
        np.testing.assert_array_equal(out, a)


def test_entropy_compresses_peaked_int8_and_small_indices():
    """The regimes the stage exists for: near-zero quantized values code far
    below 8 bits/symbol, small-range indices far below 32 — and both still
    round-trip bit-exactly (including extreme +-127 symbols)."""
    from repro.core.codec.entropy import _decode_array, _encode_array

    rng = np.random.default_rng(1)
    peaked = np.clip(np.round(rng.standard_normal(1024) * 4), -128,
                     127).astype(np.int8)
    idx = rng.integers(0, 64, size=(4, 64)).astype(np.int32)
    extremes = np.tile(np.array([-127, 127, 0], np.int8), 100)
    for a, bound in [(peaked, 0.7), (idx, 0.5), (extremes, 1.0)]:
        data = _encode_array(a)
        assert len(data) <= a.nbytes * bound + 1, (a.dtype, len(data), a.nbytes)
        out, end = _decode_array(data, 0, a.shape, a.dtype)
        assert end == len(data)
        np.testing.assert_array_equal(out, a)


# ------------------------------------------- (f) adaptive per-chunk budgets


@pytest.mark.parametrize("seed", range(20))
def test_adaptive_chunk_budgets_allocator_invariants(seed):
    """Randomized allocator sweep: the total C * k is conserved EXACTLY,
    every chunk stays in [1, d_block], and degenerate mass (zero, negative,
    non-finite) falls back to the uniform allocation."""
    rng = np.random.default_rng(seed)
    c = int(rng.integers(1, 9))
    d_block = int(rng.choice([8, 32, 64]))
    k = int(rng.integers(1, d_block + 1))
    mass = rng.uniform(0.0, 10.0, size=c) ** 4  # heavy-tailed mass
    got = codec.adaptive_chunk_budgets(mass, k, d_block)
    assert len(got) == c and sum(got) == c * k
    assert all(1 <= b <= d_block for b in got)
    # determinism: both wire ends derive the identical tuple
    assert got == codec.adaptive_chunk_budgets(mass, k, d_block)
    for bad in (np.zeros(c), -mass, np.full(c, np.nan)):
        assert codec.adaptive_chunk_budgets(bad, k, d_block) == (k,) * c


def test_adaptive_chunk_budgets_follow_mass():
    """Concentrated mass concentrates budget (clamped to d_block, the other
    chunks never go dark), proportional mass splits proportionally."""
    got = codec.adaptive_chunk_budgets([1.0, 0.0, 0.0, 0.0], k=8, d_block=64)
    assert got[0] == max(got) and got[0] > 8 and min(got) >= 1
    assert sum(got) == 32
    # clamp: one chunk can never exceed its dimension
    got = codec.adaptive_chunk_budgets([1.0, 0.0], k=16, d_block=16)
    assert got == (16, 16)
    got = codec.adaptive_chunk_budgets([3.0, 1.0], k=8, d_block=64)
    assert got == (12, 4)


def test_rand_k_chunk_budgets_unbiased_at_unchanged_bytes():
    """The chunk_budgets decode stays exactly unbiased at each chunk's own
    budget (decode scales chunk c by d/k_c), and the reallocation never
    changes the wire bytes (one flat row of sum(k_c) float32 values)."""
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D,
                                         chunk_budgets=(K // 2, K + K // 2)))
    uniform = codec.as_pipeline(codec.RandK(k=K, d_block=D))
    assert pipe.payload_nbytes(C) == uniform.payload_nbytes(C)
    xs = _clients(9)
    payload = pipe.encode_payload(jax.random.key(0), 0, xs[0])
    assert codec.check_against_schema(payload) == []
    assert payload.nbytes == pipe.payload_nbytes(C)
    xhs = _mc_estimates(pipe, xs, None, trials=200, seed=900)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err < 6 * sem + 5e-3).all(), float(err.max())


def test_chunk_budgets_validation_and_composition_gates():
    """chunk_budgets is rand_k-only, every entry lives in [1, d_block], the
    length must match the vector's chunk count, and the pipeline correctly
    declares itself non-streamable AND non-shardable."""
    with pytest.raises(ValueError, match="rand_k-only"):
        codec.RandKSpatial(k=K, d_block=D, chunk_budgets=(K, K))
    with pytest.raises(ValueError, match="chunk_budgets"):
        codec.RandK(k=K, d_block=D, chunk_budgets=(0, K))
    with pytest.raises(ValueError, match="chunk_budgets"):
        codec.RandK(k=K, d_block=D, chunk_budgets=(K, D + 1))
    sp = codec.RandK(k=K, d_block=D, chunk_budgets=(K, K, K))
    with pytest.raises(ValueError, match="3 entries"):
        sp.payload_schema(2)
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D, chunk_budgets=(4, 12)))
    assert not pipe.chunk_streamable
    assert not pipe.decode_shardable
    assert pipe.non_streamable_stage[0] is pipe.sparsifier
    assert pipe.non_shardable_stage[0] is pipe.sparsifier


def test_adaptive_budget_rounds_reallocate_without_changing_ledger():
    """RoundConfig(adaptive_budgets=True) through fl.rounds: byte-identical
    ledger to the uniform run (pure reallocation), identical round 0 (no
    previous estimate -> uniform), diverging decode once the budget vector
    starts following the estimate's per-chunk mass."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    task = get_task("dme", n_clients=RHO_N, d=4 * RHO_D, rho=0.9)
    pipe = codec.RandK(k=K, d_block=RHO_D)
    cohort = Cohort(n_clients=RHO_N)
    _, h_uni = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=4))
    _, h_ada = run_rounds(task, pipe, cohort,
                          RoundConfig(n_rounds=4, adaptive_budgets=True))
    assert h_ada.bytes == h_uni.bytes
    assert h_ada.coded_bytes == h_uni.coded_bytes
    assert h_ada.mse[0] == h_uni.mse[0]
    assert h_ada.mse[1:] != h_uni.mse[1:]
    assert np.isfinite(h_ada.mse).all()


def test_adaptive_budget_rounds_config_gates():
    """The compositions the budget vector cannot survive are rejected up
    front, by name: non-rand_k sparsifiers, dist/hier backends, async
    rounds, overlap/ownership decodes."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    task = get_task("dme", n_clients=4, d=D, rho=0.9)
    cohort = Cohort(n_clients=4)
    rand_k = codec.RandK(k=K, d_block=D)
    cases = [
        (codec.TopK(k=K, d_block=D), dict(), "rewrites rand_k"),
        (rand_k, dict(backend="gspmd"), "backend='local'"),
        (rand_k, dict(async_rounds=True), "async"),
        (rand_k, dict(ownership=True, n_owners=2), "overlap/ownership"),
    ]
    for pipe, kw, match in cases:
        cfg = RoundConfig(n_rounds=1, adaptive_budgets=True, **kw)
        with pytest.raises(ValueError, match=match):
            run_rounds(task, pipe, cohort, cfg)


# ------------------------------------------------- (g) quantizer internals


def test_salt_mask_is_full_31_bits():
    """The dither-salt regression: the named legacy salts are pinned (wire
    bit-compat with the historical payload_dtype path), derived salts use
    the FULL 31-bit crc32 mask — 'acra' and 'acsh_v' collide under the old
    27-bit typo mask (0x7FFFFFF) and must not collide under the fix."""
    import zlib

    from repro.core.codec.quantizers import _SALTS, _salt

    for name, want in _SALTS.items():
        assert _salt(name) == want
    a, b = "acra", "acsh_v"
    assert (zlib.crc32(a.encode()) & 0x7FFFFFF) == \
           (zlib.crc32(b.encode()) & 0x7FFFFFF)  # the old mask collided them
    assert _salt(a) != _salt(b)
    for name in (a, b, "aux", "norm_sq"):
        assert _salt(name) == (zlib.crc32(name.encode()) & 0x7FFFFFFF)
        assert _salt(name) == _salt(name)  # deterministic


def test_correlated_quant_requires_cohort_context():
    """Encoding CorrelatedQuant outside the pipeline (no round key / client
    id) must raise instead of silently degenerating to independent
    rounding."""
    q = codec.CorrelatedQuant()
    arrays = {"vals": jnp.ones((C, K))}
    with pytest.raises(ValueError, match="round key"):
        q.encode(jax.random.key(0), arrays, ("vals",))


def test_correlated_quant_rederivation_is_bit_exact():
    """The re-derivation contract: a client's correlated encode is a pure
    function of (round_key, client_id) — the per-client encode_payload path
    must reproduce the vmapped encode_all bits exactly (this is what lets
    the rho tracker and the stale decode re-derive payloads server-side)."""
    from repro.core.codec.payload import arrays_of

    pipe = codec.Pipeline([codec.RandK(k=K, d_block=D),
                           codec.CorrelatedQuant()])
    xs = _clients(11)
    key = jax.random.key(5)
    stacked, _ = pipe.encode_all(key, xs)
    batch = arrays_of(stacked)
    for i in range(N):
        single = arrays_of(pipe.encode_payload(key, i, xs[i]))
        for name in batch:
            np.testing.assert_array_equal(np.asarray(batch[name][i]),
                                          np.asarray(single[name]), name)


def test_correlated_beats_int8_on_shared_support():
    """The cancellation claim, in miniature: on the identity sparsifier
    (full-vector DME — every client quantizes the same coordinate) the
    cohort-stratified dither beats independent stochastic rounding on
    mean-MSE at byte-identical payloads (observed ratio ~0.6; the full-size
    gate is benchmarks' extract-quant)."""
    d, n = 256, 8
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n, 1, d)), jnp.float32)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    mses = {}
    for q_name, q_ctor in (("int8", codec.Int8Quant),
                           ("correlated", codec.CorrelatedQuant)):
        pipe = codec.Pipeline([codec.Identity(d_block=d), q_ctor()])
        xhs = _mc_estimates(pipe, xs, None, trials=64, seed=77)
        mses[q_name] = float(np.mean(np.sum((xhs - xbar[None]) ** 2,
                                            axis=(1, 2))))
    assert mses["correlated"] < 0.85 * mses["int8"], mses
    # byte parity: the win is not bought with a bigger payload
    p_int8 = codec.Pipeline([codec.Identity(d_block=d), codec.Int8Quant()])
    p_corr = codec.Pipeline([codec.Identity(d_block=d),
                             codec.CorrelatedQuant()])
    assert p_int8.payload_nbytes(1) == p_corr.payload_nbytes(1)
