"""Seeded randomized property sweeps (no third-party property-test dep).

Three invariant families, each swept over parametrized grids (>= 200 cases
total) with deterministic per-case seeds, and each run BOTH through the
monolithic decode and the new chunk-ownership sharded decode
(docs/DESIGN.md §10) — the ownership path must preserve every invariant:

(a) **Unbiasedness** — E[decode] ≈ true mean for every registered unbiased
    sparsifier x quantizer pipeline (top_k is biased by construction and
    pairs with ErrorFeedback instead; bf16's deterministic rounding gets a
    rounding-sized slack on top of the Monte-Carlo tolerance).
(b) **Lemma 4.1-style variance ordering** — at rho -> 1,
    MSE(rand_proj_spatial) <= MSE(rand_k_spatial) <= MSE(rand_k): the
    correlation-aware decoders strictly pay off where correlation exists.
(c) **Ledger honesty** — under RANDOM budgets and participant sets, the
    declared byte ledger equals the actual array bytes, ``bytes_sent``
    charges exactly the survivors, and the intra-pod columns are
    internally consistent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives
from repro.dist.sharding import chunk_ownership

D = 64
C = 2
N = 6
K = 8

# (name, sparsifier ctor) — the unbiased family (top_k excluded: biased)
UNBIASED_SPARSIFIERS = [
    ("rand_k", lambda: codec.RandK(k=K, d_block=D)),
    ("rand_k_spatial", lambda: codec.RandKSpatial(k=K, d_block=D,
                                                  transform="avg")),
    ("rand_proj_spatial", lambda: codec.RandProjSpatial(k=K, d_block=D,
                                                        transform="avg")),
    ("wangni", lambda: codec.Wangni(k=K, d_block=D)),
    ("induced", lambda: codec.Induced(k=K, d_block=D)),
    ("identity", lambda: codec.Identity(d_block=D)),
    ("sparse_proj", lambda: codec.SparseProj(k=K, d_block=D, s=8.0,
                                             transform="avg")),
]

QUANTIZERS = [
    ("none", None),
    ("bf16", codec.Bf16Quant),
    ("int8", codec.Int8Quant),
]


def _pipeline(sp_ctor, q_ctor):
    stages = [sp_ctor()]
    if q_ctor is not None:
        stages.append(q_ctor())
    return codec.Pipeline(stages)


def _clients(seed, n=N, c=C, d=D, rho=None):
    """(n, c, d) client chunks; ``rho`` close to 1 => near-identical rows."""
    rng = np.random.default_rng(seed)
    if rho is None:
        xs = rng.standard_normal((n, c, d))
    else:
        base = rng.standard_normal((c, d))
        noise = rng.standard_normal((n, c, d))
        xs = rho * base[None] + np.sqrt(max(0.0, 1 - rho**2)) * noise
    xs = xs / np.linalg.norm(xs, axis=-1, keepdims=True)
    return jnp.asarray(xs, jnp.float32)


def _mc_estimates(pipe, xs, plan, trials, seed):
    """(trials, C, d) decodes under independent round keys; the decode runs
    owner-partitioned when ``plan`` is given."""
    n = xs.shape[0]

    @jax.jit
    def one(key):
        payloads, _ = pipe.encode_all(key, xs)
        if plan is None:
            return pipe.decode_payload(key, payloads, n)
        return collectives.sharded_decode(pipe, key, payloads, n, plan)

    keys = jax.random.split(jax.random.key(seed), trials)
    return np.asarray(jax.lax.map(one, keys))


# ------------------------------------------------------------ (a) unbiasedness


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("q_name,q_ctor", QUANTIZERS, ids=[q for q, _ in QUANTIZERS])
@pytest.mark.parametrize("sp_name,sp_ctor", UNBIASED_SPARSIFIERS,
                         ids=[s for s, _ in UNBIASED_SPARSIFIERS])
@pytest.mark.parametrize("seed", [0, 1])
def test_unbiasedness_sparsifier_x_quantizer(sp_name, sp_ctor, q_name, q_ctor,
                                             seed, ownership):
    """E[decode] ≈ mean for every unbiased sparsifier x quantizer pipeline,
    monolithic AND owner-partitioned (72 cases)."""
    pipe = _pipeline(sp_ctor, q_ctor)
    xs = _clients(seed)
    plan = chunk_ownership(C, 2) if ownership else None
    xhs = _mc_estimates(pipe, xs, plan, trials=160, seed=100 + seed)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    # bf16 rounding is deterministic (not unbiased): allow its rounding size
    slack = 8e-3 if q_name == "bf16" else 5e-3
    assert (err < 6 * sem + slack).all(), (pipe.describe(), float(err.max()))


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("projection", ["srht", "subsample"])
@pytest.mark.parametrize("seed", [0, 1])
def test_unbiasedness_fused_decode_routes(projection, seed, ownership):
    """Unbiasedness survives the fused kernel decode (docs/DESIGN.md §3.5)
    through BOTH decode routes — monolithic and owner-partitioned — for the
    CG resolvent solve (srht; the ridge eps is compensated exactly by the
    recalibrated beta) and the diagonal closed form (subsample)."""
    pipe = codec.as_pipeline(codec.RandProjSpatial(
        k=K, d_block=D, transform="avg", projection=projection,
        decode_method="fused"))
    xs = _clients(seed, rho=0.9)
    plan = chunk_ownership(C, 2) if ownership else None
    xhs = _mc_estimates(pipe, xs, plan, trials=160, seed=500 + seed)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err < 6 * sem + 5e-3).all(), (projection, float(err.max()))


def test_top_k_is_biased_hence_excluded():
    """The counter-property: top_k's E[decode] != mean (that is WHY it pairs
    with ErrorFeedback and sits outside the unbiased sweep)."""
    pipe = codec.as_pipeline(codec.TopK(k=4, d_block=D))
    xs = _clients(3)
    xhs = _mc_estimates(pipe, xs, None, trials=160, seed=3)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    err = np.abs(xhs.mean(0) - xbar)
    sem = xhs.std(0) / np.sqrt(xhs.shape[0]) + 1e-4
    assert (err > 6 * sem + 5e-3).any()


# ------------------------------------------- (b) variance ordering at rho -> 1


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("k", [4, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lemma_41_variance_ordering_high_rho(n, k, seed, ownership):
    """At rho -> 1 the paper's ordering holds (24 cases):

        MSE(rand_proj_spatial) <= MSE(rand_k_spatial) <= MSE(rand_k)

    and survives the owner-partitioned decode unchanged."""
    xs = _clients(seed, n=n, c=1, rho=0.995)
    plan = chunk_ownership(1, 2) if ownership else None
    xbar = np.asarray(jnp.mean(xs, axis=0))

    def mc_mse(spec):
        pipe = codec.as_pipeline(spec)
        xhs = _mc_estimates(pipe, xs, plan, trials=150, seed=200 + seed)
        return float(np.mean(np.sum((xhs - xbar[None]) ** 2, axis=(1, 2))))

    mse_rk = mc_mse(codec.RandK(k=k, d_block=D))
    mse_rks = mc_mse(codec.RandKSpatial(k=k, d_block=D, transform="avg"))
    mse_rps = mc_mse(codec.RandProjSpatial(k=k, d_block=D, transform="avg"))
    # small MC slack; the expected gaps are factors, not percents
    assert mse_rps <= mse_rks * 1.05, (mse_rps, mse_rks)
    assert mse_rks <= mse_rk * 1.05, (mse_rks, mse_rk)
    assert mse_rps < mse_rk * 0.9, (mse_rps, mse_rk)


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
def test_sparse_proj_variance_ordering_high_rho(ownership):
    """Lemma 4.1-style ordering for the cheap-encode member: at rho -> 1
    SparseProj's Gram-resolvent decode never loses to plain Rand-k at equal
    budget, and wins clearly on average across the (n, k, seed) grid —
    correlation-awareness survives the very-sparse maps."""
    plan = chunk_ownership(1, 2) if ownership else None
    ratios = []
    for n in (4, 8):
        for k in (4, 8):
            for seed in range(3):
                xs = _clients(seed, n=n, c=1, rho=0.995)
                xbar = np.asarray(jnp.mean(xs, axis=0))

                def mc_mse(spec):
                    pipe = codec.as_pipeline(spec)
                    xhs = _mc_estimates(pipe, xs, plan, trials=150,
                                        seed=200 + seed)
                    return float(np.mean(np.sum((xhs - xbar[None]) ** 2,
                                                axis=(1, 2))))

                mse_rk = mc_mse(codec.RandK(k=k, d_block=D))
                mse_sp = mc_mse(codec.SparseProj(k=k, d_block=D, s=8.0,
                                                 transform="avg"))
                # per-case: never worse than rand_k modulo MC slack
                assert mse_sp <= mse_rk * 1.05, (n, k, seed, mse_sp, mse_rk)
                ratios.append(mse_sp / mse_rk)
    # aggregate: the decode pays off, not just ties (observed mean ~0.7)
    assert np.mean(ratios) < 0.9, ratios


def test_sparse_proj_density_sweep_monotone_flops_bounded_variance():
    """Sparser maps (s up) must get STRICTLY cheaper to encode while the
    decode variance stays bounded: MSE at every density within 1.25x of the
    densest map's (observed <= 1.05x; the slack is MC noise, not physics)."""
    xs = _clients(0, c=1, rho=0.9)
    xbar = np.asarray(jnp.mean(xs, axis=0))
    flops, mses = [], []
    for s in (1.0, 2.0, 4.0, 8.0, 16.0, 32.0):
        sp = codec.SparseProj(k=K, d_block=D, s=s, transform="avg")
        flops.append(sp.encode_flops_per_chunk())
        xhs = _mc_estimates(codec.as_pipeline(sp), xs, None, trials=200,
                            seed=11)
        mses.append(float(np.mean(np.sum((xhs - xbar[None]) ** 2,
                                         axis=(1, 2)))))
    assert all(a > b for a, b in zip(flops, flops[1:])), flops
    assert max(mses) <= mses[0] * 1.25, list(zip(flops, mses))


@pytest.mark.parametrize("backend", ["local", "gspmd", "shard_map"])
def test_sparse_proj_backend_parity(backend):
    """SparseProj through fl.rounds on all three backends: identical MSE
    trajectory and byte ledger (the estimator is backend-agnostic)."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    task = get_task("dme", n_clients=6, d=D, rho=0.9)
    pipe = codec.SparseProj(k=K, d_block=D, s=8.0, transform="avg")
    cohort = Cohort(n_clients=6, dropout=0.2)
    _, h_ref = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=3))
    if backend == "local":
        h_cmp = h_ref
    else:
        mesh = jax.make_mesh((jax.device_count(),), ("pod",))
        _, h_cmp = run_rounds(task, pipe, cohort,
                              RoundConfig(n_rounds=3, backend=backend,
                                          mesh=mesh))
    np.testing.assert_allclose(h_ref.mse, h_cmp.mse, rtol=1e-4, atol=1e-6)
    assert h_ref.bytes == h_cmp.bytes


# ------------------------------------------------------------ (c) ledger honesty


LEDGER_SPARSIFIERS = ["rand_k", "rand_k_spatial", "top_k", "wangni",
                      "induced", "identity"]


@pytest.mark.parametrize("ownership", [False, True],
                         ids=["monolithic", "ownership"])
@pytest.mark.parametrize("seed", range(60))
def test_ledger_honesty_random_budgets_participants(seed, ownership):
    """120 randomized cases: random sparsifier/quantizer/budget/participant
    draws; the declared schema must equal the actual payload bytes, the
    collectives ledger must charge exactly the survivors, and the intra-pod
    columns must be internally consistent."""
    rng = np.random.default_rng(seed)
    name = LEDGER_SPARSIFIERS[rng.integers(len(LEDGER_SPARSIFIERS))]
    d_block = int(rng.choice([32, 64, 128]))
    # wangni's fixed-capacity packing needs capacity_slots <= d_block
    k_hi = d_block // 2 if name == "wangni" else d_block
    k = int(rng.integers(1, k_hi + 1))
    q_name, q_ctor = QUANTIZERS[rng.integers(len(QUANTIZERS))]
    kw = {"transform": "avg"} if name == "rand_k_spatial" else {}
    if name == "identity":
        stages = [codec.Identity(d_block=d_block)]
    else:
        stages = [codec.SPARSIFIERS[name](k=k, d_block=d_block, **kw)]
    if q_ctor is not None:
        stages.append(q_ctor())
    pipe = codec.Pipeline(stages)

    n_total = int(rng.integers(2, 9))
    n_part = int(rng.integers(1, n_total + 1))
    if name == "rand_k_spatial" and n_part == 1:
        # the avg/opt interpolations are undefined at n=1 (rho = R/(n-1));
        # fl.server.resolve_pipeline rewrites to "one" — mirror it here
        stages[0] = stages[0].replace(transform="one")
        pipe = codec.Pipeline(stages)
    participants = np.sort(rng.choice(n_total, n_part, replace=False))
    d_flat = int(rng.integers(d_block, 4 * d_block + 1))
    tree = {"x": jnp.asarray(rng.standard_normal((n_total, d_flat)),
                             jnp.float32)}
    n_owners = int(rng.integers(2, 5)) if ownership else None

    key = jax.random.key(seed)
    _, info, _ = collectives.compressed_mean_tree(
        pipe, key, tree, participants=participants,
        ownership=n_owners,
    )

    # declared ledger == actual payload bytes for a real encode
    payload = pipe.encode_payload(key, 0, jnp.zeros((info["n_chunks"], d_block)))
    assert codec.check_against_schema(payload) == []
    assert payload.nbytes == pipe.payload_nbytes(info["n_chunks"])

    # the collectives ledger charges exactly the survivors
    assert info["n_clients"] == n_part
    assert info["n_total"] == n_total
    assert info["bytes_sent"] == n_part * pipe.payload_nbytes(info["n_chunks"])

    # intra-pod columns: the taken route's column is THE column, and the
    # standalone model reproduces the info dict exactly
    if ownership:
        assert info["n_shards"] == n_owners
        assert info["intra_pod_bytes"] == info["intra_pod_bytes_ownership"]
        model = collectives.intra_pod_traffic(
            pipe, n_part, info["n_chunks"], n_owners,
            plan=chunk_ownership(info["n_chunks"], n_owners))
        assert model == {k: info[k] for k in model}
    else:
        assert info["intra_pod_bytes"] == 0  # single logical shard


@pytest.mark.parametrize("seed", range(12))
def test_ledger_honesty_heterogeneous_budget_rounds(seed):
    """Randomized budget-group cohorts through fl.rounds: the per-round byte
    ledger equals the sum of each group's declared payload bytes, with and
    without ownership (24 cases)."""
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds

    rng = np.random.default_rng(1000 + seed)
    n_clients = int(rng.integers(4, 9))
    budgets = tuple(int(rng.choice([4, 8, 16])) for _ in range(n_clients))
    task = get_task("dme", n_clients=n_clients, d=D, rho=0.9, seed=seed)
    pipe = codec.RandK(k=8, d_block=D)
    cohort = Cohort(n_clients=n_clients, dropout=float(rng.uniform(0, 0.4)),
                    budgets=budgets)
    cfgs = [RoundConfig(n_rounds=2, seed=seed),
            RoundConfig(n_rounds=2, seed=seed, ownership=True, n_owners=2)]
    hists = [run_rounds(task, pipe, cohort, cfg)[1] for cfg in cfgs]
    for hist in hists:
        for t in range(2):
            part = cohort.sample_round(seed, t)
            want = sum(
                codec.as_pipeline(pipe.replace(k=k_g)).payload_nbytes(1)
                * len(ids_g)
                for k_g, ids_g in cohort.budget_groups(part.survivors, pipe.k)
            )
            assert hist.bytes[t] == want
    # ownership changes the server's internal routing, never the wire ledger
    assert hists[0].bytes == hists[1].bytes
    assert hists[0].mse == hists[1].mse
