"""The codec pipeline API (ISSUE 3): ledger honesty, stage-composition
unbiasedness, the legacy flat-keyword construction surface, true per-client
Rand-k-Temporal, and error feedback under heterogeneous budgets."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.core.estimators import base as est_base
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

jax.config.update("jax_platform_name", "cpu")

D, C = 64, 2  # d_block, chunks

ALL_SPARSIFIERS = [
    codec.RandK(k=8, d_block=D),
    codec.RandKSpatial(k=8, d_block=D, transform="avg"),
    codec.RandKSpatial(k=8, d_block=D, transform="avg", r_mode="est"),
    codec.RandProjSpatial(k=8, d_block=D, transform="avg"),
    codec.RandProjSpatial(k=8, d_block=D, transform="avg", r_mode="est"),
    codec.TopK(k=8, d_block=D),
    codec.Wangni(k=8, d_block=D),
    codec.Induced(k=8, d_block=D),
    codec.Identity(d_block=D),
]
QUANT_STAGES = [None, codec.Bf16Quant(), codec.Int8Quant()]


def _xs(n=6, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.standard_normal(D)
    xs = np.stack([shared + 0.3 * rng.standard_normal((C, D)) for _ in range(n)])
    return jnp.asarray(xs, jnp.float32)


# ------------------------------------------------------------ ledger honesty


@pytest.mark.parametrize("quant", QUANT_STAGES,
                         ids=["f32", "bf16", "int8"])
@pytest.mark.parametrize("sp", ALL_SPARSIFIERS,
                         ids=lambda s: f"{s.name}{'-est' if getattr(s, 'r_mode', '') == 'est' else ''}")
def test_ledger_honesty_every_codec(sp, quant):
    """Payload.nbytes (actual array bytes) == meta.declared_nbytes (schema),
    for every registered sparsifier x quantizer combination — the declared
    ledger is computed from config alone, so drift (an uncounted int8 _scale
    array, a forgotten aux stat) cannot hide."""
    stages = [sp] + ([quant] if quant is not None else [])
    pipe = codec.Pipeline(stages)
    payload = pipe.encode_payload(jax.random.key(0), 3, _xs()[0])
    problems = codec.check_against_schema(payload)
    assert not problems, problems
    assert payload.nbytes == payload.meta.declared_nbytes
    assert payload.meta.declared_nbytes == pipe.payload_nbytes(C)
    # stacked payloads: per-client actual bytes still match the declaration
    stacked, _ = pipe.encode_all(jax.random.key(1), _xs())
    assert stacked.per_client_nbytes() == pipe.payload_nbytes(C)


def test_ledger_catches_undeclared_array():
    pipe = codec.Pipeline([codec.RandK(k=8, d_block=D)])
    payload = pipe.encode_payload(jax.random.key(0), 0, _xs()[0])
    payload.arrays["sneaky_scale"] = jnp.ones((C, 1))
    problems = codec.check_against_schema(payload)
    assert any("sneaky_scale" in p for p in problems)


def test_payload_meta_budget_rides_the_payload():
    pipe = codec.Pipeline([codec.RandK(k=8, d_block=D)])
    payload = pipe.encode_payload(jax.random.key(0), 0, _xs()[0])
    assert payload.meta.budget == 8 and payload.meta.d_block == D
    # a decoder configured at a DIFFERENT budget trusts the payload's meta
    other = codec.Pipeline([codec.RandK(k=16, d_block=D)])
    stacked, _ = pipe.encode_all(jax.random.key(1), _xs())
    a = other.decode_payload(jax.random.key(1), stacked, 6)
    b = pipe.decode_payload(jax.random.key(1), stacked, 6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ----------------------------------------------- composition unbiasedness


UNBIASED = [
    codec.RandK(k=8, d_block=D),
    codec.RandKSpatial(k=8, d_block=D, transform="avg"),
    codec.RandProjSpatial(k=8, d_block=D, transform="avg"),
    codec.Wangni(k=8, d_block=D),
    codec.Induced(k=8, d_block=D),
]


@pytest.mark.parametrize("with_side", [False, True], ids=["plain", "side_info"])
@pytest.mark.parametrize("sp", UNBIASED, ids=lambda s: s.name)
def test_pipeline_int8_composition_stays_unbiased(sp, with_side):
    """Property (ISSUE 3): Pipeline([<any unbiased sparsifier>, Int8Quant()])
    keeps E[decode] = mean(x), with and without temporal side information."""
    n = 6
    xs = _xs(n)
    pipe = codec.Pipeline([sp, codec.Int8Quant()])
    side = 0.5 * jnp.mean(xs, axis=0) if with_side else None
    xbar = np.asarray(jnp.mean(xs, axis=0))

    @jax.jit
    def one(key):
        return pipe.mean_estimate(key, xs, side_info=side)

    xhs = np.asarray(jax.lax.map(one, jax.random.split(jax.random.key(2), 600)))
    sem = xhs.std(0) / np.sqrt(len(xhs)) + 1e-4
    err = np.abs(xhs.mean(0) - xbar)
    assert (err < 6 * sem + 6e-3).all(), float(err.max())


# --------------------------------------------- legacy construction surface


def test_estimator_spec_is_gone():
    """The deprecated flat EstimatorSpec shim was removed: the class no
    longer exists anywhere on the public surface, and as_pipeline's error
    for spec-shaped strangers points at codec.build."""
    import repro.core
    import repro.core.estimators

    assert not hasattr(est_base, "EstimatorSpec")
    assert not hasattr(repro.core, "EstimatorSpec")
    assert not hasattr(repro.core.estimators, "EstimatorSpec")
    assert not hasattr(codec, "spec_to_pipeline")
    with pytest.raises(TypeError, match="expected Pipeline or sparsifier"):
        codec.as_pipeline(object())


def test_build_covers_old_flat_keywords():
    """codec.build is the keyword-compatible successor: the old flat spec
    fields (payload_dtype, ef, wangni_capacity, induced_topk_frac, renames)
    all land on the right typed stage configs."""
    pipe = codec.build("rand_proj_spatial", k=8, d_block=D,
                       payload_dtype="int8", ef=True)
    assert pipe.name == "rand_proj_spatial" and pipe.has_ef
    assert isinstance(pipe.quantizer, codec.Int8Quant)
    pw = codec.build("wangni", k=8, d_block=D, wangni_capacity=2.0)
    assert pw.sparsifier.capacity == 2.0
    pi = codec.build("induced", k=8, d_block=D, induced_topk_frac=0.25)
    assert pi.sparsifier.topk_frac == 0.25
    # first-party construction never warns (nothing deprecated left to trip)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        codec.build("rand_k", k=4, d_block=D)


def test_build_numeric_parity_with_explicit_pipeline():
    """build(...) and the hand-composed Pipeline produce IDENTICAL payloads
    and decodes for the same key (key derivation and int8 salts agree)."""
    xs = _xs()
    key = jax.random.key(5)
    for kw, stages in (
        (dict(), []),
        (dict(payload_dtype="int8"), [codec.Int8Quant()]),
        (dict(payload_dtype="bfloat16"), [codec.Bf16Quant()]),
    ):
        built = codec.build("rand_proj_spatial", k=8, d_block=D,
                            transform="avg", **kw)
        sp = codec.RandProjSpatial(k=8, d_block=D, transform="avg")
        pipe = codec.Pipeline([sp] + stages)
        a = built.mean_estimate(key, xs)
        b = pipe.mean_estimate(key, xs)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_build_rejects_unknown_fields_but_tolerates_legacy():
    with pytest.raises(TypeError, match="no field"):
        codec.build("rand_k", k=8, d_block=D, klingon=True)
    # legacy spec fields that do not apply are dropped (old flat behaviour)
    pipe = codec.build("rand_k", k=8, d_block=D, transform="one")
    assert pipe.transform is None


def test_pipeline_validation():
    with pytest.raises(ValueError, match="sparsifier"):
        codec.Pipeline([codec.Int8Quant()])
    with pytest.raises(ValueError, match="more than one"):
        codec.Pipeline([codec.RandK(k=4, d_block=D), codec.Identity(d_block=D)])
    with pytest.raises(TypeError):
        codec.Pipeline([codec.RandK(k=4, d_block=D), "not a stage"])


# ------------------------------------------- per-client temporal (satellite)


def test_per_client_temporal_beats_broadcast_on_drift():
    """ISSUE acceptance: true per-client Rand-k-Temporal (client-held
    memories in ClientState) beats the broadcast variant on a drifting task
    with persistent per-client offsets, at identical bytes."""
    task = get_task("drift", n_clients=8, d=2 * D, rho=0.95, omega=0.03,
                    client_bias=1.0)
    cohort = Cohort(n_clients=8)
    per_client = codec.Pipeline([codec.RandK(k=16, d_block=D), codec.Temporal()])
    broadcast = codec.RandK(k=16, d_block=D)
    _, h_pc = run_rounds(task, per_client, cohort, RoundConfig(n_rounds=30))
    _, h_bc = run_rounds(task, broadcast, cohort,
                         RoundConfig(n_rounds=30, temporal=True))
    assert h_pc.total_bytes == h_bc.total_bytes
    # compare after the per-client memories have warmed (eta = k/d per round)
    assert np.mean(h_pc.mse[15:]) < 0.7 * np.mean(h_bc.mse[15:])
    # the final client state carries the warmed memories
    assert h_pc.client_state is not None
    assert h_pc.client_state.memory.shape[0] == 8


def test_client_temporal_memory_tracks_clients():
    """Each client's memory converges toward ITS vector, not the mean."""
    task = get_task("drift", n_clients=4, d=D, rho=0.9, omega=0.0,
                    client_bias=1.0, seed=3)
    pipe = codec.Pipeline([codec.RandK(k=16, d_block=D), codec.Temporal()])
    _, hist = run_rounds(task, pipe, Cohort(n_clients=4),
                         RoundConfig(n_rounds=40))
    mem = np.asarray(hist.client_state.memory)[:, 0, :]  # (n, d)
    key = jax.random.fold_in(jax.random.key(0), 39)
    xs = np.asarray(task.client_vectors({"t": 39, "mean": None}, key))
    xbar = xs.mean(0)
    for i in range(4):
        d_own = np.linalg.norm(mem[i] - xs[i])
        d_mean = np.linalg.norm(mem[i] - xbar)
        assert d_own < d_mean, (i, d_own, d_mean)


def test_client_temporal_on_gspmd_matches_local():
    """Per-client temporal memories now ride the collectives backends
    (ROADMAP item): the server mirrors each surviving client's memory update
    by re-running the deterministic encode, so decode trajectory, byte
    ledger, AND the final memory state all match the local backend — under
    partial participation and dropout, where the scatter of partial cohorts
    back into the full state matters."""
    n, d = 6, 2 * D
    task = get_task("drift", n_clients=n, d=d, rho=0.9, omega=0.03,
                    client_bias=1.0)
    cohort = Cohort(n_clients=n, participation=0.9, dropout=0.2)
    pipe = codec.Pipeline([codec.RandK(k=16, d_block=D), codec.Temporal()])
    _, h_local = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=6))
    _, h_gspmd = run_rounds(task, pipe, cohort,
                            RoundConfig(n_rounds=6, backend="gspmd"))
    assert h_local.bytes == h_gspmd.bytes
    np.testing.assert_allclose(h_local.mse, h_gspmd.mse, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(h_local.client_state.memory),
        np.asarray(h_gspmd.client_state.memory), rtol=1e-4, atol=1e-6)


def test_client_temporal_on_shard_map_matches_local():
    """Same mirror on the shard_map backend."""
    n, d = 6, 2 * D
    task = get_task("drift", n_clients=n, d=d, rho=0.9, omega=0.03,
                    client_bias=1.0)
    cohort = Cohort(n_clients=n, dropout=0.2)
    pipe = codec.Pipeline([codec.RandK(k=16, d_block=D), codec.Temporal()])
    mesh = jax.make_mesh((1,), ("pod",))
    _, h_local = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=5))
    _, h_sm = run_rounds(task, pipe, cohort,
                         RoundConfig(n_rounds=5, backend="shard_map",
                                     mesh=mesh))
    assert h_local.bytes == h_sm.bytes
    np.testing.assert_allclose(h_local.mse, h_sm.mse, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(h_local.client_state.memory),
        np.asarray(h_sm.client_state.memory), rtol=1e-4, atol=1e-6)


# --------------------------------- EF x heterogeneous budgets (satellite)


def test_ef_with_heterogeneous_budgets_composes():
    """The old fl.rounds rejection is lifted: error feedback now operates per
    budget group (each client's residual follows its own k_i). Regression at
    two budget groups: runs, ledgers per-k_i, and on a gradient-descent task
    (where updates ACCUMULATE — the regime EF's guarantee is about) the EF
    run converges below the biased plain-Top-k run."""
    n, d = 6, D
    budgets = (8, 8, 8, 16, 16, 16)
    task = get_task("linear_regression", n_clients=n, d=d, samples=300)
    cohort = Cohort(n_clients=n, budgets=budgets)
    with_ef = codec.Pipeline([codec.TopK(k=8, d_block=d), codec.ErrorFeedback()])
    without = codec.TopK(k=8, d_block=d)
    _, h_ef = run_rounds(task, with_ef, cohort, RoundConfig(n_rounds=40))
    _, h_plain = run_rounds(task, without, cohort, RoundConfig(n_rounds=40))
    # ledger: every round, sum over clients of C * (k_i vals + k_i idx) * 4
    c = d // D
    want = sum(c * b * 8 for b in budgets)
    assert h_ef.bytes == [want] * 40 == h_plain.bytes
    # EF keeps flushing the mass plain Top-k silently drops
    assert np.mean(h_ef.metric[-10:]) < 0.8 * np.mean(h_plain.metric[-10:])
    # residual rows exist for every client at its own budget
    assert h_ef.client_state.ef.shape == (n, c, d)


def test_heterogeneous_budgets_on_gspmd_matches_local():
    """ISSUE acceptance: heterogeneous-budget cohorts decode on the gspmd
    backend, with per-client byte ledgers summing to the local totals."""
    n, d = 6, 2 * D
    task = get_task("dme", n_clients=n, d=d, rho=0.8)
    cohort = Cohort(n_clients=n, participation=1.0, dropout=0.2,
                    budgets=(8, 8, 16, 16, 32, 32))
    pipe = codec.RandProjSpatial(k=16, d_block=D, transform="avg",
                                 use_pallas="never")
    _, h_local = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=4))
    _, h_gspmd = run_rounds(task, pipe, cohort,
                            RoundConfig(n_rounds=4, backend="gspmd"))
    assert h_local.bytes == h_gspmd.bytes
    np.testing.assert_allclose(h_local.mse, h_gspmd.mse, rtol=1e-4, atol=1e-6)


def test_heterogeneous_budgets_on_shard_map_matches_local():
    """Budget groups loop over the shard_map collective too (ROADMAP item):
    ledger and decode parity with the local backend under dropout."""
    n, d = 6, 2 * D
    task = get_task("dme", n_clients=n, d=d, rho=0.8)
    cohort = Cohort(n_clients=n, budgets=(8, 8, 16, 16, 32, 32), dropout=0.2)
    pipe = codec.RandK(k=16, d_block=D)
    mesh = jax.make_mesh((1,), ("pod",))
    _, h_local = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=3))
    _, h_sm = run_rounds(task, pipe, cohort,
                         RoundConfig(n_rounds=3, backend="shard_map", mesh=mesh))
    assert h_local.bytes == h_sm.bytes
    np.testing.assert_allclose(h_local.mse, h_sm.mse, rtol=1e-4, atol=1e-6)


def test_ef_heterogeneous_budgets_on_gspmd():
    """EF + heterogeneous budgets compose on the collectives backend too."""
    n, d = 4, D
    task = get_task("dme", n_clients=n, d=d, rho=0.7)
    cohort = Cohort(n_clients=n, budgets=(8, 8, 16, 16))
    pipe = codec.Pipeline([codec.TopK(k=8, d_block=d), codec.ErrorFeedback()])
    _, h_local = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=5))
    _, h_gspmd = run_rounds(task, pipe, cohort,
                            RoundConfig(n_rounds=5, backend="gspmd"))
    np.testing.assert_allclose(h_local.mse, h_gspmd.mse, rtol=1e-4, atol=1e-6)
    assert h_local.bytes == h_gspmd.bytes


# ------------------------------------------------------- state mechanics


def test_client_state_is_a_pytree():
    st = codec.ClientState(ef=jnp.ones((4, 2, D)), memory=None)
    leaves = jax.tree.leaves(st)
    assert len(leaves) == 1 and leaves[0].shape == (4, 2, D)
    doubled = jax.tree.map(lambda a: 2 * a, st)
    assert isinstance(doubled, codec.ClientState)
    assert float(doubled.ef[0, 0, 0]) == 2.0 and doubled.memory is None


def test_ef_stage_residual_matches_collectives_buffer():
    """The ClientState EF path (fl.rounds local) and the raw ef_chunks buffer
    path (dist.collectives) implement the same residual recursion."""
    from repro.dist import collectives

    n, d, k = 4, D, 8
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal((n, 1, d)), jnp.float32)
    pipe = codec.Pipeline([codec.TopK(k=k, d_block=d), codec.ErrorFeedback()])
    # pipeline/state path
    st = pipe.init_client_state(n, 1)
    key = jax.random.key(7)
    _, st2 = pipe.encode_all(key, xs, states=st)
    # collectives/buffer path
    _, _, ef = collectives.compressed_mean_tree(pipe, key, {"x": xs[:, 0, :]})
    np.testing.assert_allclose(np.asarray(st2.ef), np.asarray(ef),
                               rtol=1e-6, atol=1e-6)
