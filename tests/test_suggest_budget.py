"""codec.suggest_budget: the Johnson-Lindenstrauss budget auto-picker.

Golden closed-form values, the monotonicity contract, the named
BudgetExceedsDimension error (with its actionable loosen-eps hint), and the
round trip through ``fl.run --budget auto``.
"""
import math

import pytest

from repro.core import codec
from repro.core.codec.budget import jl_min_k

# hand-computed goldens: ceil(4 ln(n) / (eps^2/2 - eps^3/3))
GOLDEN = [
    (10, 0.5, 111),
    (100, 0.5, 222),
    (10, 0.3, 256),
    (2, 0.5, 34),
    (1000, 0.9, 171),
]


@pytest.mark.parametrize("n,eps,want", GOLDEN)
def test_jl_min_k_matches_closed_form(n, eps, want):
    assert jl_min_k(n, eps) == want
    # and the formula itself, independently of the goldens
    denom = eps**2 / 2.0 - eps**3 / 3.0
    assert jl_min_k(n, eps) == math.ceil(4.0 * math.log(n) / denom)


def test_suggest_budget_returns_bound_when_it_fits():
    assert codec.suggest_budget(10, 0.5, 128) == 111
    assert codec.suggest_budget(10, 0.5, 111) == 111  # boundary: k == d fits


def test_monotone_in_n_clients():
    ks = [codec.suggest_budget(n, 0.5, 4096) for n in (2, 5, 10, 100, 10_000)]
    assert ks == sorted(ks)
    assert ks[0] < ks[-1]


def test_monotone_in_eps():
    ks = [codec.suggest_budget(50, eps, 100_000)
          for eps in (0.05, 0.1, 0.2, 0.5, 0.9)]
    assert ks == sorted(ks, reverse=True)
    assert ks[0] > ks[-1]


def test_raises_named_error_when_bound_exceeds_dimension():
    with pytest.raises(codec.BudgetExceedsDimension) as ei:
        codec.suggest_budget(10, 0.3, 128)  # bound is 256 > 128
    msg = str(ei.value)
    assert "k=256" in msg and "d=128" in msg
    assert "loosen eps" in msg
    # the hint is actionable: the suggested eps actually fits
    hint = float(msg.split(">= ")[1].split()[0])
    assert codec.suggest_budget(10, hint, 128) <= 128
    # it is a ValueError so generic callers need no new except clause
    assert isinstance(ei.value, ValueError)


def test_infeasible_dimension_does_not_hint_a_fake_eps():
    """When NO eps in (0, 1) fits (the bound at eps -> 1 still exceeds d),
    the error must say so instead of hinting a loosen-eps threshold that
    cannot work — the old message claimed '>= 0.999 suffices' here, which
    was false."""
    assert jl_min_k(10, 0.999) > 32  # the premise: even eps -> 1 needs k > d
    with pytest.raises(codec.BudgetExceedsDimension) as ei:
        codec.suggest_budget(10, 0.5, 32)
    msg = str(ei.value)
    assert "no eps in (0, 1) fits" in msg
    assert "suffices" not in msg  # no fake actionable hint
    assert "shrink the cohort" in msg


@pytest.mark.parametrize("bad_eps", [0.0, 1.0, -0.1, 1.5])
def test_rejects_out_of_range_eps(bad_eps):
    with pytest.raises(ValueError, match="eps"):
        codec.suggest_budget(10, bad_eps, 128)


def test_rejects_degenerate_cohort_and_dimension():
    with pytest.raises(ValueError, match="n_clients"):
        codec.suggest_budget(1, 0.5, 128)
    with pytest.raises(ValueError, match="d must be"):
        codec.suggest_budget(10, 0.5, 0)


# ------------------------------------------------------- fl.run --budget auto


def test_budget_auto_round_trips_through_fl_run():
    """--budget auto must hand the decoded spec EXACTLY the JL k (smoke dme:
    d_block = 128, 10 clients, default --jl-eps 0.5 => k = 111), overriding
    --k entirely."""
    from repro.fl import run as fl_run

    args = fl_run.build_parser().parse_args(
        ["--task", "dme", "--smoke", "--budget", "auto", "--k", "7"])
    task = fl_run.make_task(args)
    spec, _, hist = fl_run.run_one(task, args, "rand_k", {})
    assert spec.k == codec.suggest_budget(task.n_clients, 0.5, spec.d_block)
    assert spec.k == 111
    assert len(hist.mse) == 3  # the run actually went through


def test_budget_auto_propagates_named_error():
    """An unattainable --jl-eps fails loudly with the named error, not a
    silently clamped k."""
    from repro.fl import run as fl_run

    args = fl_run.build_parser().parse_args(
        ["--task", "dme", "--smoke", "--budget", "auto", "--jl-eps", "0.3"])
    task = fl_run.make_task(args)
    with pytest.raises(codec.BudgetExceedsDimension):
        fl_run.run_one(task, args, "rand_k", {})


def test_budget_manual_ignores_jl_eps():
    from repro.fl import run as fl_run

    args = fl_run.build_parser().parse_args(
        ["--task", "dme", "--smoke", "--k", "16", "--jl-eps", "0.3"])
    task = fl_run.make_task(args)
    spec, _, _ = fl_run.run_one(task, args, "rand_k", {})
    assert spec.k == 16
