"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas


def _mk(nkv, rep, sq, sk, dh, dtype, seed=0):
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (nkv * rep, sq, dh), dtype)
    k = jax.random.normal(k2, (nkv, sk, dh), dtype)
    v = jax.random.normal(k3, (nkv, sk, dh), dtype)
    return q, k, v


@pytest.mark.parametrize("rep", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_causal(rep, dtype):
    q, k, v = _mk(2, rep, 256, 256, 64, dtype)
    got = flash_attention_pallas(q, k, v, rep=rep, q_tile=128, kv_tile=128)
    want = ref.flash_attention_ref(q, k, v, rep=rep)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_sliding_window():
    q, k, v = _mk(1, 2, 256, 256, 64, jnp.float32, seed=1)
    got = flash_attention_pallas(q, k, v, rep=2, window=64, q_tile=64, kv_tile=64)
    want = ref.flash_attention_ref(q, k, v, rep=2, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=1e-4)


def test_flash_decode_offset():
    """Sq < Sk with q_offset: cross-attention over a prefix (prefill tail)."""
    q, k, v = _mk(2, 1, 128, 512, 128, jnp.float32, seed=2)
    got = flash_attention_pallas(q, k, v, rep=1, q_offset=384, q_tile=128, kv_tile=128)
    want = ref.flash_attention_ref(q, k, v, rep=1, q_offset=384)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("sq,sk,qt,kt", [(128, 384, 64, 128), (512, 512, 256, 64)])
def test_flash_tile_shape_sweep(sq, sk, qt, kt):
    q, k, v = _mk(1, 2, sq, sk, 64, jnp.float32, seed=3)
    got = flash_attention_pallas(q, k, v, rep=2, q_tile=qt, kv_tile=kt)
    want = ref.flash_attention_ref(q, k, v, rep=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=1e-4)


def test_flash_fully_masked_rows_are_finite():
    """q_offset=0 rows attend only to k<=pos; row 0 sees one key — finite."""
    q, k, v = _mk(1, 1, 128, 128, 64, jnp.float32, seed=4)
    got = flash_attention_pallas(q, k, v, rep=1, window=1, q_tile=128, kv_tile=128)
    assert bool(jnp.isfinite(got).all())
