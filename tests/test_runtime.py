"""repro.runtime (ISSUE 8): pod plans, hierarchical decode exactness, the
two-tier byte ledger, and real 2-process × 2-pod execution via spawn_local.

The exactness contract under test: ``RoundConfig(hierarchy="hier")`` is
BITWISE identical to the flat path at one pod, and the multi-process run is
bitwise identical to the single-process run at any pod count (every process
decodes its owned pods and learns the rest via the KV exchange, so all
processes hold the same History).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds
from repro.runtime import (
    PodPlan,
    Topology,
    combine_records,
    combine_rho,
    cross_pod_traffic,
    free_port,
)
from repro.runtime.workers import history_arrays

D = 64


# ------------------------------------------------------------------ pod plan


def test_pod_plan_slices_and_ownership():
    plan = PodPlan(n_clients=10, n_pods=3)
    assert plan.clients_per_pod == 4
    assert [plan.slice_for(p) for p in range(3)] == [(0, 4), (4, 8), (8, 10)]
    assert plan.pod_of(0) == 0 and plan.pod_of(7) == 1 and plan.pod_of(9) == 2
    np.testing.assert_array_equal(plan.clients_of(2), [8, 9])


def test_pod_plan_restrict_preserves_order():
    plan = PodPlan(n_clients=12, n_pods=3)
    ids = np.array([9, 2, 5, 3, 11, 0])
    np.testing.assert_array_equal(plan.restrict(ids, 0), [2, 3, 0])
    np.testing.assert_array_equal(plan.restrict(ids, 2), [9, 11])
    # 1-pod plan: restrict is the identity on any id array (the bitwise
    # exactness contract rides on this)
    one = PodPlan(n_clients=12, n_pods=1)
    np.testing.assert_array_equal(one.restrict(ids, 0), ids)


def test_pod_plan_validation():
    with pytest.raises(ValueError, match="n_pods"):
        PodPlan(n_clients=4, n_pods=0)
    with pytest.raises(ValueError, match="one client per pod"):
        PodPlan(n_clients=2, n_pods=3)
    with pytest.raises(ValueError, match="out of range"):
        PodPlan(n_clients=4, n_pods=2).slice_for(2)


# ------------------------------------------------------------------- combine


def test_combine_records_single_pod_short_circuits():
    est = np.random.default_rng(0).standard_normal((2, D)).astype(np.float32)
    records = {0: {"mean": est, "n": 5}, 1: {"mean": None, "n": 0}}
    combined, n, weights = combine_records(records)
    assert n == 5 and weights == {0: 1.0}
    # unscaled: byte-identical, no *(n/n) float round-trip
    assert combined.tobytes() == est.tobytes()


def test_combine_records_weighted_mean():
    a = np.ones((1, 4), np.float32)
    b = 3 * np.ones((1, 4), np.float32)
    combined, n, weights = combine_records({0: {"mean": a, "n": 1},
                                            1: {"mean": b, "n": 3}})
    assert n == 4 and weights == {0: 0.25, 1: 0.75}
    np.testing.assert_allclose(combined, 2.5 * np.ones((1, 4)), rtol=1e-6)


def test_combine_records_empty():
    combined, n, weights = combine_records({0: {"mean": None, "n": 0}})
    assert combined is None and n == 0 and weights == {}


def test_combine_rho():
    assert combine_rho({0: {"rho": 0.5, "n": 3}}) == 0.5
    got = combine_rho({0: {"rho": 0.2, "n": 1}, 1: {"rho": 0.6, "n": 3}})
    assert abs(got - 0.5) < 1e-12
    assert combine_rho({0: {"rho": None, "n": 3}}) is None


# ---------------------------------------------------------------- byte model


def test_cross_pod_traffic_hier_beats_flat_when_nk_exceeds_d():
    """The regime the hierarchy exists for: n·k payload bytes crossing the
    DCN under flat aggregation exceed the P d-sized estimate exchanges."""
    n, k, d_block = 16, 64, 128
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=k, d_block=d_block,
                                                   transform="avg"))
    cohort = Cohort(n_clients=n)
    plan = PodPlan(n_clients=n, n_pods=2)
    survivors = np.arange(n)
    info = cross_pod_traffic(pipe, cohort, survivors, plan, n_chunks=1)
    assert info["n_pods"] == 2
    assert info["dcn_bytes"] == info["dcn_bytes_hier"]
    assert 0 < info["dcn_bytes_hier"] < info["dcn_bytes_flat"]
    # flat hierarchy ledgers no DCN traffic (single server, one site)
    flat = cross_pod_traffic(pipe, cohort, survivors, plan, n_chunks=1,
                             hierarchy="flat")
    assert flat["dcn_bytes"] == 0


# ---------------------------------------------------------------- topology


def test_topology_from_env_and_validation(monkeypatch):
    from repro.runtime import launch

    assert Topology().n_processes == 1
    with pytest.raises(ValueError):
        Topology(n_processes=2, process_id=5)
    monkeypatch.setenv(launch.ENV_NUM_PROCESSES, "4")
    monkeypatch.setenv(launch.ENV_PROCESS_ID, "2")
    monkeypatch.setenv(launch.ENV_COORDINATOR, "127.0.0.1:1234")
    topo = Topology.from_env()
    assert (topo.n_processes, topo.process_id) == (4, 2)
    assert topo.coordinator == "127.0.0.1:1234"
    assert 0 < free_port() < 65536


# ------------------------------------------- exactness (in-process, 1 pod)


def _drift_setup(n=8, d=2 * D):
    task = get_task("drift", n_clients=n, d=d, rho=0.9, omega=0.05,
                    client_bias=0.5)
    cohort = Cohort(n_clients=n, participation=0.9, dropout=0.2)
    pipe = codec.Pipeline([codec.RandProjSpatial(k=8, d_block=D,
                                                 transform="wavg")])
    return task, cohort, pipe


def _assert_bitwise(ha, hb):
    for key in ha:
        assert ha[key].tobytes() == hb[key].tobytes(), key


def test_hier_one_pod_bitwise_identical_to_flat():
    """RoundConfig(hierarchy="hier", pods=1) reproduces the flat path bit
    for bit — every History column, including the online-R trajectory."""
    task, cohort, pipe = _drift_setup()
    _, h_flat = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=5))
    task, cohort, pipe = _drift_setup()
    _, h_hier = run_rounds(task, pipe, cohort,
                           RoundConfig(n_rounds=5, hierarchy="hier", pods=1))
    _assert_bitwise(history_arrays(h_flat), history_arrays(h_hier))
    assert h_hier.total_dcn_bytes == 0  # one pod: nothing crosses the DCN


def test_hier_two_pods_ledgers_dcn_and_stays_close():
    """pods=2 in one process: the DCN column matches the comms model every
    round, and the two-level estimate tracks the flat one."""
    task, cohort, pipe = _drift_setup()
    cfg = RoundConfig(n_rounds=5, hierarchy="hier", pods=2)
    _, h = run_rounds(task, pipe, cohort, cfg)
    assert len(h.dcn_bytes) == 5
    assert all(b > 0 for b in h.dcn_bytes)
    task, cohort, pipe = _drift_setup()
    _, h_flat = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=5))
    assert h.bytes == h_flat.bytes  # client uplink bytes are plan-invariant
    # two pods estimate from split cohorts: same order of accuracy
    assert np.mean(h.mse) < 4 * np.mean(h_flat.mse) + 1e-3


def test_hier_validation():
    task, cohort, pipe = _drift_setup()
    with pytest.raises(ValueError, match="hierarchy"):
        run_rounds(task, pipe, cohort, RoundConfig(hierarchy="nope"))
    with pytest.raises(ValueError, match="pods"):
        run_rounds(task, pipe, cohort, RoundConfig(hierarchy="hier", pods=0))
    with pytest.raises(ValueError, match="backend"):
        run_rounds(task, pipe, cohort,
                   RoundConfig(hierarchy="hier", pods=2, backend="gspmd"))


# ------------------------------------------ multi-process (slow, subprocess)
#
# spawn_local is exercised from a `python -c` child so the pytest process
# never forks JAX-initialised state; workers live in repro.runtime.workers
# (multiprocessing's spawn context re-imports them by module name).

_COMMON = textwrap.dedent(
    """
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    from repro.fl import Cohort, RoundConfig, get_task, run_rounds
    from repro.runtime import spawn_local
    from repro.runtime.workers import (
        build_pipeline, history_arrays, kv_roundtrip_worker, round_worker,
    )

    def local_reference(spec, **over):
        task = get_task(spec["task"], **dict(spec.get("task_kw", {})))
        pipe = build_pipeline(spec["stages"])
        cohort = Cohort(**dict(spec.get("cohort", {})))
        rounds = dict(spec.get("rounds", {}));  rounds.update(over)
        _, hist = run_rounds(task, pipe, cohort, RoundConfig(**rounds))
        return history_arrays(hist), hist

    def assert_bitwise(ha, hb, tag):
        for key in ha:
            assert np.asarray(ha[key]).tobytes() == \
                np.asarray(hb[key]).tobytes(), (tag, key)

    BASE = dict(
        task="drift",
        task_kw=dict(n_clients=8, d=128, rho=0.9, omega=0.05, client_bias=0.5),
        stages=[("rand_proj_spatial", dict(k=8, d_block=64, transform="wavg"))],
        cohort=dict(n_clients=8, participation=0.9, dropout=0.2),
        rounds=dict(n_rounds=3, hierarchy="hier", pods=2),
    )
    """
)

_SUBPROC_PARITY = _COMMON + textwrap.dedent(
    """
    # transport self-test: bit-exact KV roundtrip across 2 real processes
    sums = spawn_local(kv_roundtrip_worker, 2)
    assert sums[0] == sums[1], sums

    # 2 processes x 2 pods == 1 process x 2 pods, bitwise, on every process
    outs = spawn_local(round_worker, 2, args=(BASE,))
    ref, _ = local_reference(BASE)
    for out in outs:
        assert_bitwise(ref, out, f"2proc-2pod p{out['process_id']}")

    # 2 processes x 1 pod == flat single-process, bitwise (process 1 owns
    # no pods and still converges to the same History via the exchange)
    one = dict(BASE, rounds=dict(BASE["rounds"], pods=1))
    outs1 = spawn_local(round_worker, 2, args=(one,))
    flat, _ = local_reference(BASE, hierarchy="flat", pods=1)
    for out in outs1:
        assert_bitwise(flat, out, f"2proc-1pod p{out['process_id']}")

    # DCN tier <= flat all-payloads-to-one-server bytes in the n*k > d
    # regime (acceptance): uplink payload bytes crossing pod boundaries
    # under flat aggregation vs P d-sized estimate exchanges
    big = dict(
        task="drift",
        task_kw=dict(n_clients=16, d=128, rho=0.9, omega=0.05,
                     client_bias=0.5),
        stages=[("rand_proj_spatial",
                 dict(k=64, d_block=128, transform="avg"))],
        cohort=dict(n_clients=16),
        rounds=dict(n_rounds=2, hierarchy="hier", pods=2),
    )
    outs_big = spawn_local(round_worker, 2, args=(big,))
    from repro.runtime import PodPlan, cross_pod_traffic
    pipe = build_pipeline(big["stages"])
    plan = PodPlan(n_clients=16, n_pods=2)
    info = cross_pod_traffic(pipe, Cohort(n_clients=16), np.arange(16),
                             plan, n_chunks=1)
    per_round = outs_big[0]["dcn_bytes"]
    assert (per_round > 0).all()
    assert (per_round <= info["dcn_bytes_flat"]).all(), \
        (per_round, info["dcn_bytes_flat"])
    print("RUNTIME_PARITY_OK", int(outs_big[0]["total_dcn_bytes"]))
    """
)

_SUBPROC_VARIANTS = _COMMON + textwrap.dedent(
    """
    # the decode variants that stress per-pod state: EF residuals,
    # heterogeneous budgets (per-group decode inside each pod), async
    # staleness-1 admission (per-pod stale sub-decode)
    VARIANTS = {
        "ef": dict(BASE, stages=[("top_k", dict(k=8, d_block=64)),
                                 ("error_feedback", dict())]),
        "hetero": dict(BASE, cohort=dict(BASE["cohort"],
                                         budgets=(4, 4, 8, 8, 8, 8, 16, 16))),
        "async": dict(BASE, rounds=dict(BASE["rounds"], async_rounds=True)),
    }
    for tag, spec in VARIANTS.items():
        outs = spawn_local(round_worker, 2, args=(spec,))
        ref, _ = local_reference(spec)
        for out in outs:
            assert_bitwise(ref, out, f"{tag} p{out['process_id']}")
    print("RUNTIME_VARIANTS_OK")
    """
)


def _run_subproc(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600, env=env,
    )


_SUBPROC_PSUM = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.runtime import psum_scatter_mean

    mesh = jax.make_mesh((4,), ("pod",))
    rng = np.random.default_rng(0)
    for C in (3, 4, 8):  # ragged and exact chunk tilings
        tiles = jnp.asarray(rng.standard_normal((4, C, 16)), jnp.float32)
        counts = jnp.asarray([2.0, 3.0, 1.0, 4.0])
        got = psum_scatter_mean(tiles, counts, mesh, axis="pod")
        want = np.einsum("p,pcd->cd", np.asarray(counts),
                         np.asarray(tiles)) / 10.0
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-5, atol=1e-6)
    print("PSUM_SCATTER_OK")
    """
)


@pytest.mark.slow
def test_psum_scatter_mean_on_real_mesh():
    """Pre-placed payload tiles reduce to the weighted mean on a 4-device
    mesh, including ragged chunk counts (padded psum_scatter splits)."""
    out = _run_subproc(_SUBPROC_PSUM)
    assert "PSUM_SCATTER_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_two_process_hier_matches_single_process():
    out = _run_subproc(_SUBPROC_PARITY)
    assert "RUNTIME_PARITY_OK" in out.stdout, \
        out.stdout[-1000:] + out.stderr[-2000:]


@pytest.mark.slow
def test_two_process_hier_variants_match_single_process():
    out = _run_subproc(_SUBPROC_VARIANTS)
    assert "RUNTIME_VARIANTS_OK" in out.stdout, \
        out.stdout[-1000:] + out.stderr[-2000:]
