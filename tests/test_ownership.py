"""Sharded server decode (chunk ownership, docs/DESIGN.md §10).

The tentpole claims pinned here:

1. **Plan** — `dist.sharding.ChunkOwnership` partitions the chunk grid into
   contiguous owner slices, divisibility-aware (exact tiling when divisible,
   logical padding otherwise), with every chunk owned by exactly one shard.
2. **Decode parity** — the owner-partitioned decode is BIT-identical to the
   monolithic decode for every registered estimator (position-keyed codecs
   re-derive randomness from the global chunk offset), through
   `sharded_decode`, `compressed_mean_tree(ownership=)`,
   `compressed_mean_tree_shardmap(ownership=)` (real `all_to_all` routing in
   an 8-device subprocess), and `fl.rounds` on all three backends —
   including participants masks, heterogeneous budgets, error feedback and
   overlap streaming.
3. **Ledger** — `info`/`History` gain the modelled `intra_pod_bytes`
   columns, and the ownership route strictly reduces intra-pod traffic at
   n_shards >= 2 whenever remote payload bytes exceed the decoded vector's
   d bytes.
4. **Rejection** — cross-chunk decode statistics (`rand_k_spatial` with
   `r_mode="est"`) are rejected with the offending stage named, never
   silently mis-decoded.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives
from repro.dist.sharding import ChunkOwnership, chunk_ownership
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

D = 128
K = 16


def _tree(np_rng, n=6):
    return {
        "w": jnp.asarray(np_rng.standard_normal((n, 40, 20)), jnp.float32),
        "b": jnp.asarray(np_rng.standard_normal((n, 33)), jnp.float32),
    }


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------- the plan


def test_ownership_plan_divisible():
    plan = chunk_ownership(12, 4)
    assert plan.chunks_per_owner == 3
    assert plan.pad == 0 and plan.padded_chunks == 12
    assert plan.slices == ((0, 3), (3, 6), (6, 9), (9, 12))


def test_ownership_plan_ragged_pads_tail():
    plan = chunk_ownership(7, 3)
    assert plan.chunks_per_owner == 3
    assert plan.pad == 2 and plan.padded_chunks == 9
    assert plan.slices == ((0, 3), (3, 6), (6, 7))
    # every real chunk owned by exactly one shard, in slice order
    owners = [plan.owner_of(c) for c in range(7)]
    assert owners == [0, 0, 0, 1, 1, 1, 2]
    covered = [c for lo, hi in plan.slices for c in range(lo, hi)]
    assert covered == list(range(7))


def test_ownership_plan_more_shards_than_chunks():
    plan = chunk_ownership(2, 4)
    assert plan.chunks_per_owner == 1
    assert plan.slices == ((0, 1), (1, 2), (2, 2), (2, 2))  # empty tail owners


def test_ownership_plan_validates():
    with pytest.raises(ValueError, match="n_chunks"):
        ChunkOwnership(n_chunks=0, n_shards=2)
    with pytest.raises(ValueError, match="n_shards"):
        ChunkOwnership(n_chunks=4, n_shards=0)
    plan = chunk_ownership(4, 2)
    with pytest.raises(ValueError, match="out of range"):
        plan.owner_of(4)
    with pytest.raises(ValueError, match="out of range"):
        plan.slice_for(2)


# --------------------------------------------------- owner-sliced decode core


ALL_ESTIMATORS = [
    codec.RandK(k=K, d_block=D),
    codec.RandK(k=K, d_block=D, shared_randomness=False),
    codec.RandKSpatial(k=K, d_block=D, transform="avg"),
    codec.RandProjSpatial(k=K, d_block=D, transform="avg"),
    codec.RandProjSpatial(k=K, d_block=D, transform="avg",
                          shared_randomness=False),
    codec.TopK(k=K, d_block=D),
    codec.Wangni(k=K, d_block=D),
    codec.Induced(k=K, d_block=D),
    codec.Identity(d_block=D),
    codec.SparseProj(k=K, d_block=D, transform="avg"),
    codec.SparseProj(k=K, d_block=D, transform="avg",
                     shared_randomness=False),
    codec.Pipeline([codec.RandK(k=K, d_block=D), codec.Int8Quant()]),
    codec.Pipeline([codec.RandProjSpatial(k=K, d_block=D), codec.Bf16Quant()]),
    codec.Pipeline([codec.SparseProj(k=K, d_block=D), codec.Int8Quant()]),
]

# rand_proj_spatial's online R-hat is a PER-CHUNK statistic (shardable), but
# its einsum contraction associates differently for different slice widths:
# numerically identical under ownership, not bitwise.
APPROX_ESTIMATORS = [
    codec.RandProjSpatial(k=K, d_block=D, transform="avg", r_mode="est"),
]


@pytest.mark.parametrize("spec", ALL_ESTIMATORS,
                         ids=lambda s: codec.as_pipeline(s).describe())
@pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 7])
def test_sharded_decode_bitwise_parity(spec, n_shards, rng_key, np_rng):
    """Owner-partitioned decode == monolithic decode, bit for bit, for every
    registered sparsifier x quantizer — including ragged plans (7 % 3 != 0)
    and more shards than chunks territory."""
    n, c = 6, 7
    pipe = codec.as_pipeline(spec)
    xs = jnp.asarray(np_rng.standard_normal((n, c, D)), jnp.float32)
    payloads, _ = pipe.encode_all(rng_key, xs)
    full = pipe.decode_payload(rng_key, payloads, n)
    sharded = collectives.sharded_decode(
        pipe, rng_key, payloads, n, chunk_ownership(c, n_shards)
    )
    np.testing.assert_array_equal(np.asarray(full), np.asarray(sharded))


@pytest.mark.parametrize("spec", APPROX_ESTIMATORS,
                         ids=lambda s: codec.as_pipeline(s).describe())
def test_sharded_decode_est_mode_allclose(spec, rng_key, np_rng):
    n, c = 6, 7
    pipe = codec.as_pipeline(spec)
    xs = jnp.asarray(np_rng.standard_normal((n, c, D)), jnp.float32)
    payloads, _ = pipe.encode_all(rng_key, xs)
    full = pipe.decode_payload(rng_key, payloads, n)
    for n_shards in (2, 3):
        sharded = collectives.sharded_decode(
            pipe, rng_key, payloads, n, chunk_ownership(c, n_shards)
        )
        np.testing.assert_allclose(np.asarray(full), np.asarray(sharded),
                                   rtol=1e-4, atol=1e-5)


def test_sharded_decode_with_participants(rng_key, np_rng):
    n, c = 8, 5
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    xs = jnp.asarray(np_rng.standard_normal((n, c, D)), jnp.float32)
    ids = jnp.asarray([1, 3, 6])
    payloads, _ = pipe.encode_all(rng_key, xs[jnp.asarray(ids)], client_ids=ids)
    full = pipe.decode_payload(rng_key, payloads, 3, client_ids=ids)
    sharded = collectives.sharded_decode(
        pipe, rng_key, payloads, 3, chunk_ownership(c, 2), client_ids=ids
    )
    np.testing.assert_array_equal(np.asarray(full), np.asarray(sharded))


def test_sharded_decode_rejects_cross_chunk_statistics(rng_key, np_rng):
    """rand_k_spatial(r_mode='est') pools its R-hat across chunks: the
    rejection must name the offending stage class."""
    pipe = codec.as_pipeline(
        codec.RandKSpatial(k=K, d_block=D, transform="avg", r_mode="est"))
    assert not pipe.decode_shardable
    xs = jnp.asarray(np_rng.standard_normal((4, 4, D)), jnp.float32)
    payloads, _ = pipe.encode_all(rng_key, xs)
    with pytest.raises(ValueError, match="RandKSpatial") as ei:
        collectives.sharded_decode(pipe, rng_key, payloads, 4,
                                   chunk_ownership(4, 2))
    assert "decode-shardable" in str(ei.value)
    assert "R-hat" in str(ei.value)


def test_sharded_decode_rejects_sparse_proj_pooled_rhat(rng_key, np_rng):
    """sparse_proj(r_mode='est') pools its exact-adjoint R-hat across ALL
    chunks into one scalar (sparse rows overlap, so there is no per-chunk
    norm identity to shard on): the rejection must name SparseProj."""
    pipe = codec.as_pipeline(
        codec.SparseProj(k=K, d_block=D, transform="avg", r_mode="est"))
    assert not pipe.decode_shardable
    xs = jnp.asarray(np_rng.standard_normal((4, 4, D)), jnp.float32)
    payloads, _ = pipe.encode_all(rng_key, xs)
    with pytest.raises(ValueError, match="SparseProj") as ei:
        collectives.sharded_decode(pipe, rng_key, payloads, 4,
                                   chunk_ownership(4, 2))
    assert "decode-shardable" in str(ei.value)
    assert "R-hat" in str(ei.value)
    # ...and the fixed-transform modes shard bitwise (ALL_ESTIMATORS above):
    # the gate is about the pooled statistic, not the sparsifier per se.


# ------------------------------------------------------- tree-level ownership


@pytest.mark.parametrize("spec", ALL_ESTIMATORS,
                         ids=lambda s: codec.as_pipeline(s).describe())
def test_tree_ownership_parity_gspmd(spec, rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(spec)
    m0, i0, _ = collectives.compressed_mean_tree(pipe, rng_key, tree)
    m1, i1, _ = collectives.compressed_mean_tree(pipe, rng_key, tree,
                                                 ownership=3)
    _assert_trees_equal(m0, m1)
    assert i1["n_shards"] == 3
    assert i1["intra_pod_bytes"] == i1["intra_pod_bytes_ownership"]
    assert i0["intra_pod_bytes"] == 0  # single logical shard, nothing crosses


def test_tree_ownership_with_participants_and_ef(rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.Pipeline([codec.RandK(k=K, d_block=D), codec.ErrorFeedback()])
    part = [0, 2, 5]
    m0, _, e0 = collectives.compressed_mean_tree(
        pipe, rng_key, tree, participants=part)
    m1, _, e1 = collectives.compressed_mean_tree(
        pipe, rng_key, tree, participants=part, ownership=4)
    _assert_trees_equal(m0, m1)
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))


def test_tree_ownership_composes_with_overlap(rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    m0, _, _ = collectives.compressed_mean_tree(pipe, rng_key, tree)
    for tile in (1, 2, 5):
        m1, _, _ = collectives.compressed_mean_tree(
            pipe, rng_key, tree, ownership=3, overlap=True, overlap_tile=tile)
        _assert_trees_equal(m0, m1)


def test_tree_ownership_plan_mismatch_raises(rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D))
    with pytest.raises(ValueError, match="covers"):
        collectives.compressed_mean_tree(
            pipe, rng_key, tree, ownership=chunk_ownership(3, 2))


def test_shardmap_ownership_parity_one_device(rng_key, np_rng):
    """The shard_map route (all_to_all + all_gather of means) on however many
    local devices exist — the full multi-shard parity runs in the
    subprocess test below."""
    tree = _tree(np_rng)
    mesh = jax.make_mesh((jax.device_count(),), ("pod",))
    pipe = codec.as_pipeline(codec.RandProjSpatial(k=K, d_block=D))
    m0, _, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh)
    m1, i1, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh, ownership=True)
    _assert_trees_equal(m0, m1)
    m2, _, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh, ownership=True, overlap=True,
        overlap_tile=2)
    _assert_trees_equal(m0, m2)


# ------------------------------------------------------- intra-pod byte model


def test_intra_pod_traffic_reduction_regime():
    """At n_shards >= 2 the ownership route strictly reduces intra-pod bytes
    whenever remote clients' payload bytes exceed the decoded vector's
    d bytes ((n - n/s) * payload > C * d * 4), and the model says so."""
    pipe = codec.as_pipeline(codec.RandK(k=64, d_block=128))
    for n_shards in (2, 4, 8):
        t = collectives.intra_pod_traffic(pipe, n=16, n_chunks=8,
                                          n_shards=n_shards)
        assert t["intra_pod_bytes_ownership"] < t["intra_pod_bytes_allgather"]
    # inverted regime: tiny payloads, huge vector -> ownership loses, and the
    # model must say THAT too (the ledger is honest, not a sales pitch)
    tiny = codec.as_pipeline(codec.RandK(k=1, d_block=1024))
    t = collectives.intra_pod_traffic(tiny, n=2, n_chunks=8, n_shards=2)
    assert t["intra_pod_bytes_ownership"] > t["intra_pod_bytes_allgather"]


def test_intra_pod_traffic_single_shard_is_zero():
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D))
    t = collectives.intra_pod_traffic(pipe, n=8, n_chunks=4, n_shards=1)
    assert t["intra_pod_bytes_allgather"] == 0
    assert t["intra_pod_bytes_ownership"] == 0
    assert t["intra_pod_bytes"] == 0


def test_intra_pod_reduction_helper():
    from repro.fl import server as server_lib

    pipe = codec.as_pipeline(codec.RandK(k=64, d_block=128))
    t = collectives.intra_pod_traffic(pipe, n=16, n_chunks=8, n_shards=4)
    r = server_lib.intra_pod_reduction(t)
    assert r is not None and r > 1.0
    assert server_lib.intra_pod_reduction(
        collectives.intra_pod_traffic(pipe, 16, 8, 1)) is None


def test_info_columns_present_on_both_entry_points(rng_key, np_rng):
    tree = _tree(np_rng)
    pipe = codec.as_pipeline(codec.RandK(k=K, d_block=D))
    _, info, _ = collectives.compressed_mean_tree(pipe, rng_key, tree)
    for k in ("n_shards", "intra_pod_bytes", "intra_pod_bytes_allgather",
              "intra_pod_bytes_ownership"):
        assert k in info
    mesh = jax.make_mesh((jax.device_count(),), ("pod",))
    _, info2, _ = collectives.compressed_mean_tree_shardmap(
        pipe, rng_key, tree, mesh, ownership=True)
    assert info2["n_shards"] == jax.device_count()


# ------------------------------------------------------------------ fl rounds


@pytest.mark.parametrize("backend", ["local", "gspmd", "shard_map"])
def test_rounds_ownership_parity(backend):
    """The fl acceptance: ownership decoding changes neither the MSE
    trajectory nor the transmitted-byte ledger on any backend."""
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=K, d_block=D, transform="avg")
    cohort = Cohort(n_clients=8, dropout=0.2)
    mesh = None if backend == "local" else jax.make_mesh(
        (jax.device_count(),), ("pod",))
    base = dict(n_rounds=4, backend=backend, mesh=mesh)
    _, h0 = run_rounds(task, pipe, cohort, RoundConfig(**base))
    _, h1 = run_rounds(task, pipe, cohort,
                       RoundConfig(**base, ownership=True, n_owners=4))
    assert h0.mse == h1.mse
    assert h0.bytes == h1.bytes
    # the ownership run ledgers its modelled intra-pod traffic per round
    assert len(h1.intra_pod_bytes) == 4
    if backend == "local":
        assert all(b > 0 for b in h1.intra_pod_bytes)
        assert all(b == 0 for b in h0.intra_pod_bytes)


def test_rounds_ownership_heterogeneous_budgets():
    """Owners see mixed per-client k_i: budget groups decode independently
    through the sharded path, and the trajectory matches the unsharded one."""
    budgets = (8, 8, 8, 32, 32, 32, 16, 16)
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandK(k=K, d_block=D)
    cohort = Cohort(n_clients=8, dropout=0.2, budgets=budgets)
    _, h0 = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=4))
    _, h1 = run_rounds(task, pipe, cohort,
                       RoundConfig(n_rounds=4, ownership=True, n_owners=2))
    assert h0.mse == h1.mse
    assert h0.bytes == h1.bytes


def test_rounds_ownership_composes_with_overlap_and_async():
    task = get_task("drift", n_clients=8, d=D, rho=0.95, omega=0.02)
    pipe = codec.RandProjSpatial(k=K, d_block=D, transform="avg")
    cohort = Cohort(n_clients=8, dropout=0.3)
    base = dict(n_rounds=5)
    _, h0 = run_rounds(task, pipe, cohort, RoundConfig(**base))
    _, h1 = run_rounds(task, pipe, cohort, RoundConfig(
        **base, ownership=True, n_owners=3, overlap=True, overlap_tile=2))
    assert h0.mse == h1.mse
    _, h2 = run_rounds(task, pipe, cohort, RoundConfig(**base,
                                                       async_rounds=True))
    _, h3 = run_rounds(task, pipe, cohort, RoundConfig(
        **base, async_rounds=True, ownership=True, n_owners=3))
    assert h2.mse == h3.mse and h2.bytes == h3.bytes
    assert sum(h3.n_stale) == sum(h2.n_stale)


def test_rounds_ownership_composes_with_ef_and_temporal():
    task = get_task("drift", n_clients=6, d=D, rho=0.95, omega=0.02,
                    client_bias=0.5)
    cohort = Cohort(n_clients=6, dropout=0.2)
    for stages in ([codec.RandK(k=K, d_block=D), codec.ErrorFeedback()],
                   [codec.RandK(k=K, d_block=D), codec.Temporal()]):
        pipe = codec.Pipeline(stages)
        _, h0 = run_rounds(task, pipe, cohort, RoundConfig(n_rounds=4))
        _, h1 = run_rounds(task, pipe, cohort,
                           RoundConfig(n_rounds=4, ownership=True, n_owners=3))
        assert h0.mse == h1.mse


def test_rounds_ownership_rejects_cross_chunk_decode():
    task = get_task("dme", n_clients=4, d=D, rho=0.9)
    pipe = codec.RandKSpatial(k=K, d_block=D, transform="avg", r_mode="est")
    with pytest.raises(ValueError, match="RandKSpatial"):
        run_rounds(task, pipe, cfg=RoundConfig(n_rounds=1, ownership=True,
                                               n_owners=2))


# ------------------------------------------------------------------ train step


def test_train_step_ownership_parity():
    from repro import configs
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.optim import AdamW
    from repro.train import make_train_step

    cfg = configs.reduce_for_smoke(configs.get_config("musicgen-medium"))
    opt = AdamW(lr=1e-2, warmup_steps=1)
    params = init_params(cfg, jax.random.key(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch=3,
                       n_clients=2)
    batch = data.batch_at(0)
    spec = codec.build("rand_k", k=64, d_block=512)
    s0 = jax.jit(make_train_step(cfg, opt, dme_spec=spec))
    s1 = jax.jit(make_train_step(cfg, opt, dme_spec=spec, dme_ownership=4))
    p0, _, m0 = s0(params, {"opt": opt.init(params)}, batch, 0)
    p1, _, m1 = s1(params, {"opt": opt.init(params)}, batch, 0)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert m1["intra_pod_reduction"] > 0


# ---------------------------------------------- real multi-shard routing (slow)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import codec
    from repro.dist import collectives

    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((8, 40, 20)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((8, 33)), jnp.float32)}
    mesh = jax.make_mesh((4,), ("pod",))

    specs = [
        codec.RandProjSpatial(k=16, d_block=128),
        codec.RandK(k=16, d_block=128, shared_randomness=False),
        codec.Wangni(k=16, d_block=128),
        codec.Induced(k=16, d_block=128),
        codec.Identity(d_block=128),
        codec.Pipeline([codec.RandK(k=16, d_block=128), codec.Int8Quant()]),
    ]
    for spec in specs:
        pipe = codec.as_pipeline(spec)
        # warm any beta eigenvalue bank OUTSIDE the mesh trace: the
        # host-side bank simulation cannot run inside shard_map
        collectives.compressed_mean_tree(pipe, key, tree)
        m0, i0, _ = collectives.compressed_mean_tree_shardmap(
            pipe, key, tree, mesh)
        m1, i1, _ = collectives.compressed_mean_tree_shardmap(
            pipe, key, tree, mesh, ownership=True)
        for a, b in zip(jax.tree.leaves(m0), jax.tree.leaves(m1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert i1["n_shards"] == 4

    # participants + EF + overlap through the real all_to_all routing
    pipe_ef = codec.Pipeline([codec.RandK(k=16, d_block=128),
                              codec.ErrorFeedback()])
    m2, _, e2 = collectives.compressed_mean_tree_shardmap(
        pipe_ef, key, tree, mesh, participants=[0, 2, 5, 6, 7])
    m3, _, e3 = collectives.compressed_mean_tree_shardmap(
        pipe_ef, key, tree, mesh, participants=[0, 2, 5, 6, 7],
        ownership=True)
    for a, b in zip(jax.tree.leaves(m2), jax.tree.leaves(m3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(e3))
    m4, _, e4 = collectives.compressed_mean_tree_shardmap(
        pipe_ef, key, tree, mesh, ownership=True, overlap=True,
        overlap_tile=2)
    m5, _, e5 = collectives.compressed_mean_tree_shardmap(
        pipe_ef, key, tree, mesh)
    for a, b in zip(jax.tree.leaves(m4), jax.tree.leaves(m5)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(e4), np.asarray(e5))

    # the reduction regime, measured off the real route's info dict: n*k
    # payload bytes per chunk >> d bytes per chunk (warm the beta bank
    # OUTSIDE the mesh trace; host-side simulation cannot run inside it)
    big = {"w": jnp.asarray(rng.standard_normal((8, 64, 32)), jnp.float32)}
    pipe_big = codec.as_pipeline(
        codec.RandProjSpatial(k=64, d_block=128, beta_trials=8))
    collectives.compressed_mean_tree(pipe_big, key, big)
    mb0, ib0, _ = collectives.compressed_mean_tree_shardmap(
        pipe_big, key, big, mesh)
    mb1, ib1, _ = collectives.compressed_mean_tree_shardmap(
        pipe_big, key, big, mesh, ownership=True)
    for a, b in zip(jax.tree.leaves(mb0), jax.tree.leaves(mb1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert ib1["intra_pod_bytes_ownership"] < ib1["intra_pod_bytes_allgather"]
    print("SUBPROC_OK")
    """
)


@pytest.mark.slow
def test_shardmap_ownership_multi_shard_in_subprocess():
    """4 real shards: all_to_all payload routing + all_gather of decoded
    means is bit-identical to the replicated all-gather decode for every
    estimator family, and the intra-pod ledger reduction holds."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]
