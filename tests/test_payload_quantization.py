"""Payload quantization (sparsification x quantization — paper §7 future
work): int8 stochastic rounding keeps the composed estimator unbiased and
cuts payload bytes 4x for a small MSE premium."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core import codec, correlation, mean_estimate
from repro.core.estimators import base as est_base


def _xs(n, d, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.standard_normal(d)
    xs = np.stack([shared + 0.3 * rng.standard_normal(d) for _ in range(n)])
    return jnp.asarray(xs[:, None, :], jnp.float32)


@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
def test_quantized_payload_unbiased(dtype):
    n, d, k = 6, 128, 16
    xs = _xs(n, d)
    spec = codec.build("rand_proj_spatial", k=k, d_block=d,
                         transform="avg", payload_dtype=dtype)
    xbar = np.asarray(jnp.mean(xs, axis=0))

    @jax.jit
    def one(key):
        return mean_estimate(spec, key, xs)

    xhs = np.asarray(jax.lax.map(one, jax.random.split(jax.random.key(0), 800)))
    sem = xhs.std(0) / np.sqrt(len(xhs)) + 1e-4
    assert (np.abs(xhs.mean(0) - xbar) < 6 * sem + 6e-3).all()


def test_int8_payload_bytes_and_mse_tradeoff():
    n, d, k = 6, 256, 32
    xs = _xs(n, d, seed=1)
    key = jax.random.key(1)
    sizes, mses = {}, {}
    for dtype in ("float32", "int8"):
        spec = codec.build("rand_proj_spatial", k=k, d_block=d,
                             transform="avg", payload_dtype=dtype)
        payload = est_base.encode(spec, key, 0, xs[0])
        sizes[dtype] = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(payload))

        @jax.jit
        def one(kk, spec=spec):
            return correlation.mse(mean_estimate(spec, kk, xs), jnp.mean(xs, 0))

        mses[dtype] = float(jnp.mean(jax.lax.map(one, jax.random.split(key, 200))))
    assert sizes["int8"] < sizes["float32"] / 3  # ~4x minus the per-chunk scale
    assert mses["int8"] < mses["float32"] * 1.5  # small premium


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([8, 16, 32]))
def test_property_decode_finite_any_seed(seed, k):
    """Property: decode is finite for any round key / budget (no NaN paths)."""
    xs = _xs(4, 64, seed=seed % 1000)
    spec = codec.build("rand_proj_spatial", k=k, d_block=64,
                         transform="avg", payload_dtype="int8")
    xh = mean_estimate(spec, jax.random.key(seed), xs)
    assert bool(jnp.isfinite(xh).all())
