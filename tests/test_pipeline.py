"""Pipeline parallelism vs serial reference (4 host devices, subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import partition_blocks, pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    n_blocks, d, m, mb = 8, 16, 6, 3
    key = jax.random.key(0)
    w = jax.random.normal(key, (n_blocks, d, d)) * 0.3
    b = jax.random.normal(jax.random.fold_in(key, 1), (n_blocks, d)) * 0.1
    params = {"w": w, "b": b}
    x = jax.random.normal(jax.random.fold_in(key, 2), (m, mb, d))

    def block(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def stage_fn(stage_params, h):
        # stage_params: (blocks_per_stage, ...) -> apply sequentially
        def body(hh, p):
            return block(p, hh), None
        hh, _ = jax.lax.scan(body, h, stage_params)
        return hh

    # serial reference
    ref = x
    for i in range(n_blocks):
        ref = block(jax.tree.map(lambda l: l[i], params), ref)

    staged = partition_blocks(params, 4)
    out = pipeline_apply(stage_fn, staged, x, mesh, axis="pipe")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-6)
    print("PIPELINE_OK", float(jnp.abs(out - ref).max()))
    """
)


@pytest.mark.slow
def test_pipeline_matches_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert "PIPELINE_OK" in out.stdout, out.stderr[-2000:]


def test_partition_blocks_shapes():
    import jax.numpy as jnp

    from repro.dist.pipeline import partition_blocks

    tree = {"w": jnp.zeros((8, 3, 5)), "b": jnp.zeros((8,))}
    staged = partition_blocks(tree, 4)
    assert staged["w"].shape == (4, 2, 3, 5)
    assert staged["b"].shape == (4, 2)


# ------------------------------------------- pipelined train step (fl stack)


def _smoke_setup(n_clients=0, batch=4):
    import jax

    from repro import configs
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.optim import AdamW

    cfg = configs.reduce_for_smoke(configs.get_config("gemma3-4b"))
    opt = AdamW(lr=1e-3, warmup_steps=2)
    params = init_params(cfg, jax.random.key(0))
    data = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=32, batch=batch,
        n_clients=n_clients, seed=0,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0,
    )
    return cfg, opt, params, data.batch_at(0)


def _max_leaf_diff(a, b):
    import jax
    import jax.numpy as jnp

    return max(
        float(jnp.abs(x - y).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_pipelined_train_step_matches_plain():
    """pipeline_stages=1 routes the loss through pipeline_apply on a 1-device
    'pipe' mesh; the optimizer step must match the unpipelined step."""
    import jax

    from repro.train import make_train_step
    from repro.train.train_step import init_train_state

    cfg, opt, params, batch = _smoke_setup()
    step_ref = jax.jit(make_train_step(cfg, opt))
    mesh = jax.make_mesh((1,), ("pipe",))
    step_pipe = jax.jit(make_train_step(
        cfg, opt, mesh=mesh, pipeline_stages=1, pipeline_microbatches=2))

    p1, _, m1 = step_ref(params, init_train_state(cfg, opt, params), batch, 0)
    p2, _, m2 = step_pipe(params, init_train_state(cfg, opt, params), batch, 0)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-5
    assert _max_leaf_diff(p1, p2) < 2e-5


def test_pipelined_train_step_composes_with_dme():
    """The pipeline shard_map lives inside the per-client vmapped loss."""
    import jax

    from repro.core import codec
    from repro.train import make_train_step
    from repro.train.train_step import init_train_state

    cfg, opt, params, batch = _smoke_setup(n_clients=3, batch=2)
    dme = codec.build("rand_proj_spatial", k=32, d_block=256, transform="avg")
    step_ref = jax.jit(make_train_step(cfg, opt, dme_spec=dme))
    mesh = jax.make_mesh((1,), ("pipe",))
    step_pipe = jax.jit(make_train_step(
        cfg, opt, dme_spec=dme, mesh=mesh, pipeline_stages=1,
        pipeline_microbatches=2))

    st = init_train_state(cfg, opt, params, dme, 3)
    p1, _, m1 = step_ref(params, st, batch, 0)
    st = init_train_state(cfg, opt, params, dme, 3)
    p2, _, m2 = step_pipe(params, st, batch, 0)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-5
    assert _max_leaf_diff(p1, p2) < 2e-5


def test_pipelined_train_step_rejects_bad_configs():
    import jax
    import pytest as _pytest

    from repro.train import make_train_step

    cfg, opt, _, _ = _smoke_setup()
    with _pytest.raises(ValueError, match="mesh"):
        make_train_step(cfg, opt, pipeline_stages=2)
    mesh = jax.make_mesh((1,), ("pipe",))
    with _pytest.raises(ValueError, match="size"):
        make_train_step(cfg, opt, mesh=mesh, pipeline_stages=2)


_SUBPROC_STEP = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.data import SyntheticLM
    from repro.models import init_params
    from repro.optim import AdamW
    from repro.train import make_train_step
    from repro.train.train_step import init_train_state

    cfg = configs.reduce_for_smoke(configs.get_config("gemma3-4b"))
    assert cfg.n_blocks % 2 == 0, cfg.n_blocks
    opt = AdamW(lr=1e-3, warmup_steps=2)
    params = init_params(cfg, jax.random.key(0))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, batch=4,
                       n_clients=0, seed=0,
                       embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)
    batch = data.batch_at(0)

    step_ref = jax.jit(make_train_step(cfg, opt))
    mesh = jax.make_mesh((2,), ("pipe",))
    step_pipe = jax.jit(make_train_step(
        cfg, opt, mesh=mesh, pipeline_stages=2, pipeline_microbatches=4))
    p1, _, m1 = step_ref(params, init_train_state(cfg, opt, params), batch, 0)
    p2, _, m2 = step_pipe(params, init_train_state(cfg, opt, params), batch, 0)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-5
    md = max(float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert md < 2e-5, md
    print("PIPELINE_STEP_OK", md)
    """
)


@pytest.mark.slow
def test_pipelined_train_step_two_stages():
    """Real 2-stage GPipe on 2 host devices vs the unpipelined step."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC_STEP], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert "PIPELINE_STEP_OK" in out.stdout, out.stderr[-2000:]
