"""Pipeline parallelism vs serial reference (4 host devices, subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import partition_blocks, pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    n_blocks, d, m, mb = 8, 16, 6, 3
    key = jax.random.key(0)
    w = jax.random.normal(key, (n_blocks, d, d)) * 0.3
    b = jax.random.normal(jax.random.fold_in(key, 1), (n_blocks, d)) * 0.1
    params = {"w": w, "b": b}
    x = jax.random.normal(jax.random.fold_in(key, 2), (m, mb, d))

    def block(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def stage_fn(stage_params, h):
        # stage_params: (blocks_per_stage, ...) -> apply sequentially
        def body(hh, p):
            return block(p, hh), None
        hh, _ = jax.lax.scan(body, h, stage_params)
        return hh

    # serial reference
    ref = x
    for i in range(n_blocks):
        ref = block(jax.tree.map(lambda l: l[i], params), ref)

    staged = partition_blocks(params, 4)
    out = pipeline_apply(stage_fn, staged, x, mesh, axis="pipe")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-6)
    print("PIPELINE_OK", float(jnp.abs(out - ref).max()))
    """
)


@pytest.mark.slow
def test_pipeline_matches_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert "PIPELINE_OK" in out.stdout, out.stderr[-2000:]


def test_partition_blocks_shapes():
    import jax.numpy as jnp

    from repro.dist.pipeline import partition_blocks

    tree = {"w": jnp.zeros((8, 3, 5)), "b": jnp.zeros((8,))}
    staged = partition_blocks(tree, 4)
    assert staged["w"].shape == (4, 2, 3, 5)
    assert staged["b"].shape == (4, 2)
