"""repro.fl subsystem: round driver, participation, budgets, correlation
tracking, temporal decoding, backend parity, and the paper's Fig. 4 ordering
measured at workload level (ISSUE acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec, transforms
from repro.fl import Cohort, RoundConfig, get_task, run_rounds
from repro.fl import server as server_lib

jax.config.update("jax_platform_name", "cpu")


def test_round_driver_smoke_all_tasks():
    small = {
        "power_iteration": dict(d=128, samples=200),
        "kmeans": dict(d=32, samples=200),
        "linear_regression": dict(d=64, samples=200),
        "logistic_regression": dict(feat=16, samples=200),
        "dme": dict(d=64),
        "drift": dict(d=64),
    }
    for name, kw in small.items():
        task = get_task(name, n_clients=4, **kw)
        spec = codec.build("rand_proj_spatial", k=8, d_block=64,
                             transform="avg")
        state, hist = run_rounds(task, spec, Cohort(n_clients=4),
                                 RoundConfig(n_rounds=2))
        assert len(hist.mse) == 2 and all(b > 0 for b in hist.bytes)
        if task.metric is not None:
            assert np.isfinite(hist.metric[-1])


def test_power_iteration_converges_and_estimators_order():
    """Fig. 4 structure: the estimator family converges; identity is best."""
    task = get_task("power_iteration", n_clients=8, d=256, samples=1000)
    errs = {}
    for name in ("identity", "rand_proj_spatial"):
        spec = codec.build(name, k=26, d_block=256, transform="avg")
        state, _ = run_rounds(task, spec, Cohort(n_clients=8),
                              RoundConfig(n_rounds=10))
        errs[name] = task.metric(state)
    assert errs["identity"] < 0.2   # eigengap-limited at 10 rounds
    assert errs["rand_proj_spatial"] < 1.0  # converging (init err ~ sqrt(2))


def test_fig4_ordering_mse_at_equal_bytes_rho_09():
    """ISSUE acceptance: on a rho >= 0.9 correlated synthetic task,
    Rand-Proj-Spatial < Rand-k-Spatial < Rand-k at equal bytes (same k, same
    round keys => paired comparison)."""
    task = get_task("dme", n_clients=8, d=128, rho=0.9)
    res = {}
    for name, tf in [("rand_k", "one"), ("rand_k_spatial", "avg"),
                     ("rand_proj_spatial", "avg")]:
        spec = codec.build(name, k=16, d_block=128, transform=tf)
        _, hist = run_rounds(task, spec, Cohort(n_clients=8),
                             RoundConfig(n_rounds=50))
        res[name] = (np.mean(hist.mse), hist.total_bytes)
    # equal bytes across the family (k values per chunk, indices key-derived)
    assert res["rand_k"][1] == res["rand_k_spatial"][1] == res["rand_proj_spatial"][1]
    assert res["rand_proj_spatial"][0] < res["rand_k_spatial"][0]
    assert res["rand_k_spatial"][0] < res["rand_k"][0]


def test_temporal_beats_spatial_on_drift():
    """ISSUE acceptance: temporal decoding beats its spatial-only counterpart
    on a slowly-drifting task."""
    task = get_task("drift", n_clients=8, d=128, rho=0.95, omega=0.03)
    spec = codec.build("rand_proj_spatial", k=16, d_block=128,
                         transform="avg")
    _, h_sp = run_rounds(task, spec, Cohort(n_clients=8),
                         RoundConfig(n_rounds=20, temporal=False))
    _, h_tm = run_rounds(task, spec, Cohort(n_clients=8),
                         RoundConfig(n_rounds=20, temporal=True))
    # identical ledgers, materially lower error once warm (round 0 has no side
    # information, so compare the post-warmup averages)
    assert h_sp.total_bytes == h_tm.total_bytes
    assert np.mean(h_tm.mse[2:]) < 0.7 * np.mean(h_sp.mse[2:])


def test_wavg_tracks_correlation_online():
    """transform='wavg': the server's EMA of r_exact over decoded history
    approaches the true rho, and the resolved decode beats the blind avg."""
    rho_true = 0.9
    task = get_task("dme", n_clients=8, d=128, rho=rho_true)
    spec = codec.build("rand_proj_spatial", k=24, d_block=128,
                         transform="wavg")
    _, hist = run_rounds(task, spec, Cohort(n_clients=8),
                         RoundConfig(n_rounds=25))
    tail = [r for r in hist.rho_hat[5:] if not np.isnan(r)]
    assert len(tail) > 0
    assert abs(np.mean(tail) - rho_true) < 0.2, np.mean(tail)
    _, h_avg = run_rounds(task, spec.replace(transform="avg"),
                          Cohort(n_clients=8), RoundConfig(n_rounds=25))
    assert np.mean(hist.mse) < np.mean(h_avg.mse)


def test_wavg_rejected_outside_fl_server():
    with pytest.raises(ValueError, match="wavg"):
        transforms.rho_for("wavg", 8)
    # resolution: wavg -> avg cold, -> opt(R_ema * (n-1)) warm, -> one if n=1
    pipe = codec.build("rand_proj_spatial", transform="wavg")
    st = server_lib.ServerState()
    assert server_lib.resolve_pipeline(pipe, st, 8).transform == "avg"
    st.r_ema = 0.8
    r = server_lib.resolve_pipeline(pipe, st, 8)
    assert r.transform == "opt"
    assert r.sparsifier.r_value == pytest.approx(0.8 * 7)
    assert server_lib.resolve_pipeline(pipe, st, 1).transform == "one"
    # transform-free sparsifiers pass through the singleton rewrite untouched
    rk = server_lib.resolve_pipeline(codec.build("rand_k"), st, 1)
    assert rk.transform is None and rk.name == "rand_k"


def test_partial_participation_and_heterogeneous_budgets():
    """Identity codec is exact per budget group, so the combined decode must
    equal the survivors' exact mean; the ledger must count only survivors,
    at their own k_i."""
    n, d = 8, 128
    budgets = (8, 8, 16, 16, 16, 32, 32, 32)
    task = get_task("dme", n_clients=n, d=d, rho=0.5)
    cohort = Cohort(n_clients=n, participation=0.75, dropout=0.25,
                    budgets=budgets)
    spec = codec.build("identity", d_block=d)
    _, hist = run_rounds(task, spec, cohort, RoundConfig(n_rounds=6))
    assert max(hist.mse) < 1e-9  # exact survivor mean every round
    # some round actually saw attrition
    assert any(s < m for s, m in zip(hist.n_survivors, hist.n_sampled))
    # rand_k ledger: bytes = sum over survivors of C * k_i * 4
    spec_rk = codec.build("rand_k", k=16, d_block=d)
    _, h_rk = run_rounds(task, spec_rk, cohort, RoundConfig(n_rounds=6))
    for t in range(6):
        part = cohort.sample_round(0, t)
        want = sum(budgets[i] * 4 for i in part.survivors)
        assert h_rk.bytes[t] == want


def test_heterogeneous_budget_decode_is_unbiased():
    """Budget-grouped decode: E[mean] == survivors' mean (statistical)."""
    n, d = 6, 64
    task = get_task("dme", n_clients=n, d=d, rho=0.7)
    cohort = Cohort(n_clients=n, budgets=(8, 8, 8, 16, 16, 16))
    spec = codec.build("rand_k", k=8, d_block=d)
    ests = []
    for seed in range(150):
        _, hist = run_rounds(task, spec, cohort,
                             RoundConfig(n_rounds=1, seed=seed))
        ests.append(hist.mse[0])
    xs = np.asarray(task.aux["xs"])
    # MSE should be finite and bounded by the worst-group Rand-k bound
    worst = (1 / 3**2) * (d / 8 - 1) * np.sum(xs**2) / 2
    assert np.mean(ests) < worst


def test_backend_parity_local_gspmd_shardmap():
    task = get_task("dme", n_clients=8, d=128, rho=0.8)
    spec = codec.build("rand_proj_spatial", k=16, d_block=128,
                         transform="avg", use_pallas="never")
    cohort = Cohort(n_clients=8, participation=0.75, dropout=0.2)
    _, h_local = run_rounds(task, spec, cohort, RoundConfig(n_rounds=4))
    _, h_gspmd = run_rounds(task, spec, cohort,
                            RoundConfig(n_rounds=4, backend="gspmd"))
    np.testing.assert_allclose(h_local.mse, h_gspmd.mse, rtol=1e-4, atol=1e-6)
    mesh = jax.make_mesh((1,), ("pod",))
    _, h_sm = run_rounds(task, spec, cohort,
                         RoundConfig(n_rounds=4, backend="shard_map", mesh=mesh))
    np.testing.assert_allclose(h_local.mse, h_sm.mse, rtol=1e-4, atol=1e-6)


def test_cohort_sampling_deterministic_and_bounded():
    c = Cohort(n_clients=10, participation=0.5, dropout=0.5)
    a, b = c.sample_round(3, 7), c.sample_round(3, 7)
    np.testing.assert_array_equal(a.sampled, b.sampled)
    np.testing.assert_array_equal(a.survivors, b.survivors)
    for t in range(50):
        p = c.sample_round(0, t)
        assert p.n_sampled == 5 and 1 <= p.n_survivors <= 5
        assert set(p.survivors) <= set(p.sampled)


def test_dirichlet_and_band_partitions_skew():
    from repro.fl.clients import partition

    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 2000)
    x = rng.standard_normal((2000, 4)).astype(np.float32)

    def overlap(shards_labels):
        h0 = np.bincount(shards_labels[0], minlength=10)
        h1 = np.bincount(shards_labels[1], minlength=10)
        return np.minimum(h0, h1).sum() / max(h0.sum(), 1)

    iid = partition(labels, labels, 2, "iid")
    band = partition(labels, labels, 2, "band")
    diri = partition(labels, labels, 2, "dirichlet", alpha=0.1)
    assert overlap(band) < 0.05          # label-sorted halves barely overlap
    assert overlap(diri) < overlap(iid)  # Dir(0.1) skews class mixtures
    assert partition(x, labels, 3, "dirichlet").shape[0] == 3
