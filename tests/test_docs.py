"""Tier-1 docs hygiene: the markdown link graph must stay intact.

The full docs CI job (.github/workflows/ci.yml, ``docs``) also EXECUTES the
README / DESIGN.md / API.md python blocks; that is subprocess-heavy, so
tier-1 only pins the fast pure-file checks of tools/check_docs.py.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402


def test_markdown_links_resolve():
    assert check_docs.check_links() == []


def test_snippet_extraction_sees_quickstarts():
    """The executable-snippet harness must actually find the quickstart
    blocks — an empty extraction would make the CI job vacuously green."""
    readme = os.path.join(check_docs.REPO, "README.md")
    assert len(check_docs.python_blocks(readme)) >= 2
    api = os.path.join(check_docs.REPO, "docs", "API.md")
    assert len(check_docs.python_blocks(api)) >= 1
