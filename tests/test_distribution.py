"""Distribution layer: sharding rules, compressed-mean collective, and an
in-subprocess 8-device mesh lower+compile (keeps the main test process on
1 device as required)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import codec
from repro.data import SyntheticLM
from repro.dist import collectives
from repro.dist.sharding import MODEL_PREF, spec_for
from repro.models import init_params
from repro.optim import AdamW
from repro.train import make_train_step


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np

        self.devices = _np.empty(shape)


def test_spec_for_divisibility():
    mesh = FakeMesh((16, 16), ("data", "model"))
    # standard attn weight: heads -> model, embed -> data
    assert spec_for((5120, 5120), ("embed", "heads"), mesh) == P("data", "model")
    # non-divisible model dim falls through (3352 % 16 != 0)
    assert spec_for((768, 3352), ("embed", "mamba_inner"), mesh) == P("data", None)
    # experts not divisible (8 % 16) -> ff gets model, embed gets data
    assert spec_for((8, 6144, 16384), ("experts", "embed", "ff"), mesh) == P(None, "data", "model")
    # norm: replicated
    assert spec_for((5120,), (None,), mesh) == P(None)
    # pod axis never assigned to params
    mesh3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    assert spec_for((5120, 5120), ("embed", "heads"), mesh3) == P("data", "model")


def test_compressed_mean_identity_is_exact():
    tree = {
        "w": jnp.asarray(np.random.default_rng(0).standard_normal((3, 8, 8)), jnp.float32),
        "b": jnp.asarray(np.random.default_rng(1).standard_normal((3, 5)), jnp.float32),
    }
    spec = codec.build("identity", d_block=64)
    mean, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), tree)
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(tree["w"].mean(0)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mean["b"]), np.asarray(tree["b"].mean(0)), rtol=1e-6)
    assert info["n_clients"] == 3


def test_compressed_mean_unbiased_full_budget():
    """k == d_block: SRHT is invertible per client => exact mean recovery."""
    n, d = 4, 64
    tree = {"w": jnp.asarray(np.random.default_rng(2).standard_normal((n, d)), jnp.float32)}
    spec = codec.build("rand_proj_spatial", k=d, d_block=d, transform="max")
    mean, _, _ = collectives.compressed_mean_tree(spec, jax.random.key(1), tree)
    np.testing.assert_allclose(
        np.asarray(mean["w"]), np.asarray(tree["w"].mean(0)), rtol=1e-3, atol=1e-4
    )


def test_dme_train_step_matches_plain_with_identity():
    """dme_step(identity codec) == plain step on the flattened batch."""
    cfg = configs.reduce_for_smoke(configs.get_config("musicgen-medium"))
    opt = AdamW(lr=1e-2, warmup_steps=1)
    params = init_params(cfg, jax.random.key(0))
    n = 2
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch=3, n_clients=n)
    batch = data.batch_at(0)
    flat_batch = jax.tree.map(lambda l: l.reshape((-1,) + l.shape[2:]), batch)

    plain = jax.jit(make_train_step(cfg, opt))
    dme = jax.jit(make_train_step(
        cfg, opt, dme_spec=codec.build("identity", d_block=1024)))

    p1, s1, m1 = plain(params, {"opt": opt.init(params)}, flat_batch, 0)
    p2, s2, m2 = dme(params, {"opt": opt.init(params)}, batch, 0)
    # identical up to fp reassociation (client-mean vs batch-mean of grads)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_dme_train_step_compressed_converges_direction():
    """Compressed grad must correlate strongly with the true mean grad."""
    cfg = configs.reduce_for_smoke(configs.get_config("musicgen-medium"))
    opt = AdamW(lr=1e-2, warmup_steps=1)
    params = init_params(cfg, jax.random.key(0))
    n = 4
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch=2, n_clients=n)
    batch = data.batch_at(0)

    from jax.flatten_util import ravel_pytree
    from repro.models import transformer

    def per_client(b):
        return jax.grad(lambda p: transformer.loss_fn(p, cfg, b)[0])(params)

    grads = jax.vmap(per_client)(batch)
    spec = codec.build("rand_proj_spatial", k=256, d_block=512, transform="avg")
    mean_hat, _, _ = collectives.compressed_mean_tree(spec, jax.random.key(3), grads)
    true_mean = jax.tree.map(lambda g: g.mean(0), grads)
    gh, _ = ravel_pytree(mean_hat)
    gt, _ = ravel_pytree(true_mean)
    cos = float(jnp.dot(gh, gt) / (jnp.linalg.norm(gh) * jnp.linalg.norm(gt)))
    assert cos > 0.5, cos  # 2x compression, 4 clients: strong directional agreement


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.dist import sharding as shard_lib
    from repro.launch import specs
    from repro.optim import AdamW
    from repro.train import make_train_step
    from repro.core import codec

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = configs.reduce_for_smoke(configs.get_config("{arch}")).replace(
        vocab_pad_multiple=32)
    opt = AdamW()
    params = specs.params_specs(cfg, mesh)
    state = {{"opt": specs.opt_state_specs(opt, params)}}
    spec = codec.build("rand_proj_spatial", k=16, d_block=128, use_pallas="never")
    fn = make_train_step(cfg, opt, dme_spec=spec, mesh=mesh, client_axes=("pod",))
    import jax.numpy as jnp
    batch = {{
        "inputs": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32,
            sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("pod", "data", None))),
        "labels": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32,
            sharding=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("pod", "data", None))),
    }}
    step = jax.ShapeDtypeStruct((), jnp.int32)
    compiled = jax.jit(fn).lower(params, state, batch, step).compile()
    text = compiled.as_text()
    assert "all-gather" in text or "all-reduce" in text
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {{}}
    print("SUBPROC_OK", ca.get("flops", -1))
    """
)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["musicgen-medium", "deepseek-moe-16b"])
def test_mesh_compile_in_subprocess(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(arch=arch)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert "SUBPROC_OK" in out.stdout, out.stderr[-2000:]
