"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.fwht import fwht_pallas


@pytest.mark.parametrize("d", [2, 8, 64, 128, 256, 1024, 2048])
def test_fwht_ref_matches_matrix(d):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, d)).astype(np.float32)
    h = ref.hadamard_matrix(d)
    got = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    want = x @ h.T  # H symmetric; explicit anyway
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * np.sqrt(d))


@pytest.mark.parametrize("d", [128, 256, 512, 1024, 4096])
@pytest.mark.parametrize("rows", [1, 7, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_pallas_matches_ref(d, rows, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((rows, d)), dtype)
    got = fwht_pallas(x, interpret=True, block_rows=16)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 * d if dtype == jnp.float32 else 0.1 * d
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_encode_fused_matches_ref(d, k):
    key = jax.random.key(2)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (5, d))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    got = ops.srht_encode(x, signs, rows, use_pallas="force")
    want = ref.srht_encode_ref(x, signs, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_decode_is_adjoint(d, k):
    """<G x, u> == <x, G^T u> for all x, u."""
    key = jax.random.key(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (d,))
    u = jax.random.normal(k2, (k,))
    signs = jax.random.rademacher(k3, (d,), jnp.float32)
    rows = jax.random.permutation(k4, d)[:k]
    gx = ops.srht_encode(x[None], signs, rows)[0]
    gtu = ops.srht_decode(u[None], signs, rows, d)[0]
    np.testing.assert_allclose(
        float(jnp.dot(gx, u)), float(jnp.dot(x, gtu)), rtol=1e-4
    )


def test_srht_rows_matrix_matches_encode():
    d, k = 512, 32
    key = jax.random.key(4)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (d,))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    g = ops.srht_rows_matrix(signs, rows, d)
    np.testing.assert_allclose(
        np.asarray(g @ x), np.asarray(ops.srht_encode(x[None], signs, rows)[0]),
        rtol=1e-4, atol=1e-5,
    )
    # G G^T has orthogonal-ish rows: diag == k-independent (rows of H have norm sqrt(d))
    np.testing.assert_allclose(np.diag(np.asarray(g @ g.T)), np.ones(k), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    logd=st.integers(min_value=3, max_value=11),
    rows=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_property_involution_and_parseval(logd, rows, seed):
    """H (H x) = d x (involution), ||Hx||^2 = d ||x||^2 (Parseval)."""
    d = 1 << logd
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
    hx = ops.fwht(x)
    hhx = ops.fwht(hx)
    np.testing.assert_allclose(np.asarray(hhx), np.asarray(x) * d, rtol=2e-3, atol=1e-2 * d)
    np.testing.assert_allclose(
        np.sum(np.asarray(hx) ** 2, -1), d * np.sum(np.asarray(x) ** 2, -1), rtol=2e-3
    )
