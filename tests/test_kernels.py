"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Only the hypothesis-driven sweep at the bottom needs the [test] extra; the
golden/parity tests run everywhere (seeded randomized sweeps with no
third-party dependency live in tests/test_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fwht import _pick_block_rows, _split_dims, fwht_pallas


@pytest.mark.parametrize("d", [2, 8, 64, 128, 256, 1024, 2048])
def test_fwht_ref_matches_matrix(d):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, d)).astype(np.float32)
    h = ref.hadamard_matrix(d)
    got = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    want = x @ h.T  # H symmetric; explicit anyway
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * np.sqrt(d))


@pytest.mark.parametrize("d", [128, 256, 512, 1024, 4096])
@pytest.mark.parametrize("rows", [1, 7, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_pallas_matches_ref(d, rows, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((rows, d)), dtype)
    got = fwht_pallas(x, interpret=True, block_rows=16)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 * d if dtype == jnp.float32 else 0.1 * d
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_encode_fused_matches_ref(d, k):
    key = jax.random.key(2)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (5, d))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    got = ops.srht_encode(x, signs, rows, use_pallas="force")
    want = ref.srht_encode_ref(x, signs, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_decode_is_adjoint(d, k):
    """<G x, u> == <x, G^T u> for all x, u."""
    key = jax.random.key(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (d,))
    u = jax.random.normal(k2, (k,))
    signs = jax.random.rademacher(k3, (d,), jnp.float32)
    rows = jax.random.permutation(k4, d)[:k]
    gx = ops.srht_encode(x[None], signs, rows)[0]
    gtu = ops.srht_decode(u[None], signs, rows, d)[0]
    np.testing.assert_allclose(
        float(jnp.dot(gx, u)), float(jnp.dot(x, gtu)), rtol=1e-4
    )


def test_srht_rows_matrix_matches_encode():
    d, k = 512, 32
    key = jax.random.key(4)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (d,))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    g = ops.srht_rows_matrix(signs, rows, d)
    np.testing.assert_allclose(
        np.asarray(g @ x), np.asarray(ops.srht_encode(x[None], signs, rows)[0]),
        rtol=1e-4, atol=1e-5,
    )
    # G G^T has orthogonal-ish rows: diag == k-independent (rows of H have norm sqrt(d))
    np.testing.assert_allclose(np.diag(np.asarray(g @ g.T)), np.ones(k), rtol=1e-5)


# ------------------------------------------------------- FWHT golden tests
# The SRHT encode (G_i = (1/sqrt d) E_i H D_i) underpins every decode-parity
# claim: these pin fwht_pallas against kernels.ref across the non-square
# _split_dims factorisations (d < 128 -> a=1 lane-only; d > 128 -> a=d/128
# Kronecker two-stage), the fused sign flip, and batch rows that do not
# divide the tile height.


def test_split_dims_factorisations():
    assert _split_dims(8) == (1, 8)        # lane-only, b < 128
    assert _split_dims(64) == (1, 64)
    assert _split_dims(128) == (1, 128)
    assert _split_dims(512) == (4, 128)    # two-stage, non-square (a != b)
    assert _split_dims(4096) == (32, 128)
    for bad in (0, 1, 3, 24, 100):
        with pytest.raises(ValueError, match="power of two"):
            _split_dims(bad)


@pytest.mark.parametrize("d", [8, 64, 512, 4096])
@pytest.mark.parametrize("with_signs", [False, True])
def test_fwht_pallas_golden_vs_ref(d, with_signs):
    """scale * H (signs * x) parity across every factorisation shape, with
    the Rademacher flip fused on load (exactly the SRHT encode's form)."""
    rng = np.random.default_rng(d)
    rows = 6
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d), jnp.float32)
    scale = 1.0 / np.sqrt(d)
    got = fwht_pallas(x, signs if with_signs else None,
                      with_signs=with_signs, scale=scale, interpret=True,
                      block_rows=8)
    want = ref.fwht_ref((x * signs) if with_signs else x) * scale
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4 * np.sqrt(d)
    )


@pytest.mark.parametrize("rows", [1, 5, 9, 17])
def test_fwht_pallas_ragged_rows_pad_and_unpad(rows):
    """Batch rows that don't divide the tile height: the pad rows must be
    sliced back off and never leak into the output."""
    d = 256
    rng = np.random.default_rng(rows)
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    got = fwht_pallas(x, interpret=True, block_rows=8)  # rows % 8 != 0 cases
    assert got.shape == (rows, d)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_pick_block_rows_bounds():
    """The autotuned tile height stays a power of two, >= 8, and within the
    VMEM budget — the contract _pick_block_rows documents."""
    for n_rows, d in [(1, 128), (7, 512), (1000, 4096), (64, 1 << 16)]:
        bt = _pick_block_rows(n_rows, d)
        assert bt >= 8
        assert bt & (bt - 1) == 0
        assert bt * d <= 2 * 1024 * 1024 or bt == 8
    # and fwht_pallas accepts the default pick end-to-end on a ragged batch
    x = jnp.asarray(np.random.default_rng(0).standard_normal((7, 512)),
                    jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fwht_pallas(x, interpret=True)),
        np.asarray(ref.fwht_ref(x)), atol=1e-3)


def test_fwht_involution_and_parseval_seeded():
    """H (H x) = d x (involution), ||Hx||^2 = d ||x||^2 (Parseval) — the
    seeded no-dependency version of the hypothesis sweep below."""
    for logd, rows, seed in [(3, 1, 0), (5, 7, 1), (8, 3, 2), (11, 2, 3)]:
        d = 1 << logd
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
        hx = ops.fwht(x)
        hhx = ops.fwht(hx)
        np.testing.assert_allclose(np.asarray(hhx), np.asarray(x) * d,
                                   rtol=2e-3, atol=1e-2 * d)
        np.testing.assert_allclose(
            np.sum(np.asarray(hx) ** 2, -1),
            d * np.sum(np.asarray(x) ** 2, -1), rtol=2e-3
        )


# --------------------------- fused SRHT kernels: BITWISE golden tests
# Small-integer inputs make every +-1-Hadamard partial sum exactly
# representable in float32, and the fused kernels apply scale after the
# transform exactly where ref.py does (docs/KERNELS.md) — so kernel and
# oracle are asserted bit-for-bit equal, not allclose. Reduction order
# cannot matter when all partial sums are exact.


def _ints(rng, shape, hi=8):
    return jnp.asarray(rng.integers(-hi, hi, shape), jnp.float32)


def _signs(rng, shape):
    return jnp.asarray(rng.integers(0, 2, shape) * 2 - 1, jnp.float32)


def _draw_rows(rng, lead, k, d):
    out = np.stack([rng.permutation(d)[:k]
                    for _ in range(int(np.prod(lead)))])
    return jnp.asarray(out.reshape(*lead, k), jnp.int32)


@pytest.mark.parametrize("d", [8, 64, 512, 4096])
@pytest.mark.parametrize("rows", [1, 5, 16])
@pytest.mark.parametrize("sign_pre,sign_post",
                         [(False, False), (True, False), (False, True)])
def test_fwht_rowsigns_golden_bitwise(d, rows, sign_pre, sign_post):
    from repro.kernels.srht_fused import fwht_rowsigns_pallas

    rng = np.random.default_rng(d * 31 + rows)
    x = _ints(rng, (rows, d))
    signs = _signs(rng, (rows, d))
    scale = 0.25  # power of two => scaled sums stay exact
    got = fwht_rowsigns_pallas(x, signs, sign_pre=sign_pre,
                               sign_post=sign_post, scale=scale,
                               block_rows=8, interpret=True)
    want = ref.fwht_rowsigns_ref(x, signs, sign_pre=sign_pre,
                                 sign_post=sign_post, scale=scale)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("d", [8, 64, 512, 4096])
@pytest.mark.parametrize("c", [1, 3, 9])
@pytest.mark.parametrize("shared", [False, True], ids=["per_chunk", "shared"])
def test_srht_decode_sum_golden_bitwise(d, c, shared):
    """Fused decode reduction == scatter -> rowsigns-FWHT -> client sum,
    over ragged chunk grids, shared and per-chunk sign diagonals."""
    from repro.kernels.srht_fused import srht_decode_sum_pallas

    n, k = 3, max(1, d // 4)
    rng = np.random.default_rng(d * 7 + c + shared)
    z = _ints(rng, (n, c, k))
    rows_idx = _draw_rows(rng, (n, c), k, d)
    signs = _signs(rng, (n, 1, d) if shared else (n, c, d))
    scale = 0.125
    u = ref.srht_scatter_ref(z, rows_idx, d)
    got = srht_decode_sum_pallas(u, signs, scale=scale, block_rows=8,
                                 interpret=True)
    # oracle composition (scale placement identical to the kernel):
    t = ref.fwht_rowsigns_ref(u, jnp.broadcast_to(signs, u.shape),
                              sign_post=True, scale=scale)
    want = jnp.sum(t, axis=0)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("d", [8, 64, 512])
@pytest.mark.parametrize("c", [1, 4, 9])
@pytest.mark.parametrize("shared", [False, True], ids=["per_chunk", "shared"])
def test_srht_gram_apply_golden_bitwise(d, c, shared):
    """Fused matrix-free S v: two FWHTs + mask + client sum, bitwise vs the
    oracle (d <= 512: the double transform's partial sums must stay under
    2^24 for exactness, so the 4096 case is covered allclose at ops level)."""
    from repro.kernels.srht_fused import srht_gram_apply_pallas

    n, k = 3, max(1, d // 4)
    rng = np.random.default_rng(d * 13 + c + shared)
    v = _ints(rng, (c, d), hi=4)
    sshape = (n, 1, d) if shared else (n, c, d)
    signs = _signs(rng, sshape)
    mask_rows = _draw_rows(rng, sshape[:2], k, d)
    mask = np.zeros(sshape, np.float32)
    np.put_along_axis(mask, np.asarray(mask_rows), 1.0, axis=-1)
    mask = jnp.asarray(mask)
    # ref's scale is fixed at 1/d — a power of two for power-of-two d
    got = srht_gram_apply_pallas(v, signs, mask, scale=1.0 / d, block_rows=8,
                                 interpret=True)
    want = ref.srht_gram_apply_ref(v, signs, mask)
    assert (np.asarray(got) == np.asarray(want)).all()


@pytest.mark.parametrize("d,k", [(64, 16), (4096, 64)])
def test_fused_ops_dispatch_parity(d, k):
    """ops-level fused encode/decode: the forced interpret-mode kernel and
    the jnp oracle composition agree bitwise — integer inputs make both
    transforms exact, and the identical post-scale multiply rounds the
    same way on both paths (this is the use_pallas fallback contract)."""
    n, c = 2, 3
    rng = np.random.default_rng(d + k)
    x = _ints(rng, (n, c, d))
    signs = _signs(rng, (n, c, d))
    rows_idx = _draw_rows(rng, (n, c), k, d)
    enc_force = ops.srht_encode_batch(x, signs, rows_idx, use_pallas="force")
    enc_never = ops.srht_encode_batch(x, signs, rows_idx, use_pallas="never")
    assert (np.asarray(enc_force) == np.asarray(enc_never)).all()

    z = _ints(rng, (n, c, k))
    dec_force = ops.srht_decode_sum(z, signs, rows_idx, d, use_pallas="force")
    dec_never = ops.srht_decode_sum(z, signs, rows_idx, d, use_pallas="never")
    assert (np.asarray(dec_force) == np.asarray(dec_never)).all()

    v = _ints(rng, (c, d), hi=4)
    mask = (ref.srht_scatter_ref(jnp.ones((n, c, k), jnp.float32),
                                 rows_idx, d) > 0).astype(jnp.float32)
    g_force = ops.srht_gram_apply(v, signs, mask, use_pallas="force")
    g_never = ops.srht_gram_apply(v, signs, mask, use_pallas="never")
    np.testing.assert_allclose(np.asarray(g_force), np.asarray(g_never),
                               atol=1e-4 * d)


# ------------------------------------------------ hypothesis sweep (optional)
# A plain importorskip would skip the WHOLE module during collection; only
# this sweep needs hypothesis, so it alone is defined conditionally.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in the no-extra env
    st = None

if st is not None:

    @settings(max_examples=20, deadline=None)
    @given(
        logd=st.integers(min_value=3, max_value=11),
        rows=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_fwht_property_involution_and_parseval(logd, rows, seed):
        """H (H x) = d x (involution), ||Hx||^2 = d ||x||^2 (Parseval)."""
        d = 1 << logd
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
        hx = ops.fwht(x)
        hhx = ops.fwht(hx)
        np.testing.assert_allclose(np.asarray(hhx), np.asarray(x) * d,
                                   rtol=2e-3, atol=1e-2 * d)
        np.testing.assert_allclose(
            np.sum(np.asarray(hx) ** 2, -1),
            d * np.sum(np.asarray(x) ** 2, -1), rtol=2e-3
        )
