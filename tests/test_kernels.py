"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Only the hypothesis-driven sweep at the bottom needs the [test] extra; the
golden/parity tests run everywhere (seeded randomized sweeps with no
third-party dependency live in tests/test_properties.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fwht import _pick_block_rows, _split_dims, fwht_pallas


@pytest.mark.parametrize("d", [2, 8, 64, 128, 256, 1024, 2048])
def test_fwht_ref_matches_matrix(d):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, d)).astype(np.float32)
    h = ref.hadamard_matrix(d)
    got = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    want = x @ h.T  # H symmetric; explicit anyway
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * np.sqrt(d))


@pytest.mark.parametrize("d", [128, 256, 512, 1024, 4096])
@pytest.mark.parametrize("rows", [1, 7, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_pallas_matches_ref(d, rows, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((rows, d)), dtype)
    got = fwht_pallas(x, interpret=True, block_rows=16)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 * d if dtype == jnp.float32 else 0.1 * d
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
    )


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_encode_fused_matches_ref(d, k):
    key = jax.random.key(2)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (5, d))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    got = ops.srht_encode(x, signs, rows, use_pallas="force")
    want = ref.srht_encode_ref(x, signs, rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@pytest.mark.parametrize("d,k", [(256, 16), (1024, 64)])
def test_srht_decode_is_adjoint(d, k):
    """<G x, u> == <x, G^T u> for all x, u."""
    key = jax.random.key(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (d,))
    u = jax.random.normal(k2, (k,))
    signs = jax.random.rademacher(k3, (d,), jnp.float32)
    rows = jax.random.permutation(k4, d)[:k]
    gx = ops.srht_encode(x[None], signs, rows)[0]
    gtu = ops.srht_decode(u[None], signs, rows, d)[0]
    np.testing.assert_allclose(
        float(jnp.dot(gx, u)), float(jnp.dot(x, gtu)), rtol=1e-4
    )


def test_srht_rows_matrix_matches_encode():
    d, k = 512, 32
    key = jax.random.key(4)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (d,))
    signs = jax.random.rademacher(k2, (d,), jnp.float32)
    rows = jax.random.permutation(k3, d)[:k]
    g = ops.srht_rows_matrix(signs, rows, d)
    np.testing.assert_allclose(
        np.asarray(g @ x), np.asarray(ops.srht_encode(x[None], signs, rows)[0]),
        rtol=1e-4, atol=1e-5,
    )
    # G G^T has orthogonal-ish rows: diag == k-independent (rows of H have norm sqrt(d))
    np.testing.assert_allclose(np.diag(np.asarray(g @ g.T)), np.ones(k), rtol=1e-5)


# ------------------------------------------------------- FWHT golden tests
# The SRHT encode (G_i = (1/sqrt d) E_i H D_i) underpins every decode-parity
# claim: these pin fwht_pallas against kernels.ref across the non-square
# _split_dims factorisations (d < 128 -> a=1 lane-only; d > 128 -> a=d/128
# Kronecker two-stage), the fused sign flip, and batch rows that do not
# divide the tile height.


def test_split_dims_factorisations():
    assert _split_dims(8) == (1, 8)        # lane-only, b < 128
    assert _split_dims(64) == (1, 64)
    assert _split_dims(128) == (1, 128)
    assert _split_dims(512) == (4, 128)    # two-stage, non-square (a != b)
    assert _split_dims(4096) == (32, 128)
    for bad in (0, 1, 3, 24, 100):
        with pytest.raises(ValueError, match="power of two"):
            _split_dims(bad)


@pytest.mark.parametrize("d", [8, 64, 512, 4096])
@pytest.mark.parametrize("with_signs", [False, True])
def test_fwht_pallas_golden_vs_ref(d, with_signs):
    """scale * H (signs * x) parity across every factorisation shape, with
    the Rademacher flip fused on load (exactly the SRHT encode's form)."""
    rng = np.random.default_rng(d)
    rows = 6
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    signs = jnp.asarray(rng.choice([-1.0, 1.0], size=d), jnp.float32)
    scale = 1.0 / np.sqrt(d)
    got = fwht_pallas(x, signs if with_signs else None,
                      with_signs=with_signs, scale=scale, interpret=True,
                      block_rows=8)
    want = ref.fwht_ref((x * signs) if with_signs else x) * scale
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-4 * np.sqrt(d)
    )


@pytest.mark.parametrize("rows", [1, 5, 9, 17])
def test_fwht_pallas_ragged_rows_pad_and_unpad(rows):
    """Batch rows that don't divide the tile height: the pad rows must be
    sliced back off and never leak into the output."""
    d = 256
    rng = np.random.default_rng(rows)
    x = jnp.asarray(rng.standard_normal((rows, d)), jnp.float32)
    got = fwht_pallas(x, interpret=True, block_rows=8)  # rows % 8 != 0 cases
    assert got.shape == (rows, d)
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_pick_block_rows_bounds():
    """The autotuned tile height stays a power of two, >= 8, and within the
    VMEM budget — the contract _pick_block_rows documents."""
    for n_rows, d in [(1, 128), (7, 512), (1000, 4096), (64, 1 << 16)]:
        bt = _pick_block_rows(n_rows, d)
        assert bt >= 8
        assert bt & (bt - 1) == 0
        assert bt * d <= 2 * 1024 * 1024 or bt == 8
    # and fwht_pallas accepts the default pick end-to-end on a ragged batch
    x = jnp.asarray(np.random.default_rng(0).standard_normal((7, 512)),
                    jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fwht_pallas(x, interpret=True)),
        np.asarray(ref.fwht_ref(x)), atol=1e-3)


def test_fwht_involution_and_parseval_seeded():
    """H (H x) = d x (involution), ||Hx||^2 = d ||x||^2 (Parseval) — the
    seeded no-dependency version of the hypothesis sweep below."""
    for logd, rows, seed in [(3, 1, 0), (5, 7, 1), (8, 3, 2), (11, 2, 3)]:
        d = 1 << logd
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
        hx = ops.fwht(x)
        hhx = ops.fwht(hx)
        np.testing.assert_allclose(np.asarray(hhx), np.asarray(x) * d,
                                   rtol=2e-3, atol=1e-2 * d)
        np.testing.assert_allclose(
            np.sum(np.asarray(hx) ** 2, -1),
            d * np.sum(np.asarray(x) ** 2, -1), rtol=2e-3
        )


# ------------------------------------------------ hypothesis sweep (optional)
# A plain importorskip would skip the WHOLE module during collection; only
# this sweep needs hypothesis, so it alone is defined conditionally.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in the no-extra env
    st = None

if st is not None:

    @settings(max_examples=20, deadline=None)
    @given(
        logd=st.integers(min_value=3, max_value=11),
        rows=st.integers(min_value=1, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_fwht_property_involution_and_parseval(logd, rows, seed):
        """H (H x) = d x (involution), ||Hx||^2 = d ||x||^2 (Parseval)."""
        d = 1 << logd
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
        hx = ops.fwht(x)
        hhx = ops.fwht(hx)
        np.testing.assert_allclose(np.asarray(hhx), np.asarray(x) * d,
                                   rtol=2e-3, atol=1e-2 * d)
        np.testing.assert_allclose(
            np.sum(np.asarray(hx) ** 2, -1),
            d * np.sum(np.asarray(x) ** 2, -1), rtol=2e-3
        )
