"""dist.collectives payload accounting: ``info["bytes_sent"]`` must track the
actual wire format — k/d_block scaling for the seed-derived codecs (indices
never travel) and the payload_dtype quantization savings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives

N, D_FLAT, D_BLOCK = 4, 2048, 512  # no tail padding: 4 exact chunks


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((N, D_FLAT)), jnp.float32)}


@pytest.mark.parametrize("name", ["rand_k", "rand_proj_spatial"])
@pytest.mark.parametrize("k", [32, 64, 128])
def test_bytes_sent_scales_as_k_over_d_block(name, k):
    spec = codec.build(name, k=k, d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    assert info["n_clients"] == N
    assert info["n_chunks"] == D_FLAT // D_BLOCK
    # seed-derived indices are re-derived server-side: only k f32 values per
    # chunk cross the wire
    assert info["payload_bytes_per_client"] == info["n_chunks"] * k * 4
    assert info["bytes_sent"] == N * info["payload_bytes_per_client"]
    assert info["full_bytes"] / info["payload_bytes_per_client"] == D_BLOCK / k


def test_identity_payload_is_full_size():
    spec = codec.build("identity", d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    assert info["payload_bytes_per_client"] == info["full_bytes"] == D_FLAT * 4


def test_top_k_payload_counts_transmitted_indices():
    k = 32
    spec = codec.build("top_k", k=k, d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    # data-dependent indices DO travel: k f32 values + k int32 indices
    assert info["payload_bytes_per_client"] == info["n_chunks"] * k * (4 + 4)


@pytest.mark.parametrize("name", ["rand_k", "rand_proj_spatial"])
def test_payload_dtype_quantization_savings(name):
    k = 128
    trees = {}
    for dtype in ("float32", "bfloat16", "int8"):
        spec = codec.build(name, k=k, d_block=D_BLOCK, payload_dtype=dtype)
        _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
        trees[dtype] = info["payload_bytes_per_client"]
    c = D_FLAT // D_BLOCK
    assert trees["float32"] == c * k * 4
    assert trees["bfloat16"] == c * k * 2
    # int8: 1 byte per value + one f32 scale per chunk
    assert trees["int8"] == c * (k + 4)
    assert trees["float32"] / trees["int8"] > 3.5  # ~4x fewer bytes
