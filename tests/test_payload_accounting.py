"""dist.collectives payload accounting: ``info["bytes_sent"]`` must track the
actual wire format — k/d_block scaling for the seed-derived codecs (indices
never travel) and the payload_dtype quantization savings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.dist import collectives

N, D_FLAT, D_BLOCK = 4, 2048, 512  # no tail padding: 4 exact chunks


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((N, D_FLAT)), jnp.float32)}


@pytest.mark.parametrize("name", ["rand_k", "rand_proj_spatial", "sparse_proj"])
@pytest.mark.parametrize("k", [32, 64, 128])
def test_bytes_sent_scales_as_k_over_d_block(name, k):
    spec = codec.build(name, k=k, d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    assert info["n_clients"] == N
    assert info["n_chunks"] == D_FLAT // D_BLOCK
    # seed-derived indices are re-derived server-side: only k f32 values per
    # chunk cross the wire
    assert info["payload_bytes_per_client"] == info["n_chunks"] * k * 4
    assert info["bytes_sent"] == N * info["payload_bytes_per_client"]
    assert info["full_bytes"] / info["payload_bytes_per_client"] == D_BLOCK / k


def test_identity_payload_is_full_size():
    spec = codec.build("identity", d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    assert info["payload_bytes_per_client"] == info["full_bytes"] == D_FLAT * 4


def test_top_k_payload_counts_transmitted_indices():
    k = 32
    spec = codec.build("top_k", k=k, d_block=D_BLOCK)
    _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
    # data-dependent indices DO travel: k f32 values + k int32 indices
    assert info["payload_bytes_per_client"] == info["n_chunks"] * k * (4 + 4)


@pytest.mark.parametrize("name", ["rand_k", "rand_proj_spatial", "sparse_proj"])
def test_payload_dtype_quantization_savings(name):
    k = 128
    trees = {}
    for dtype in ("float32", "bfloat16", "int8"):
        spec = codec.build(name, k=k, d_block=D_BLOCK, payload_dtype=dtype)
        _, info, _ = collectives.compressed_mean_tree(spec, jax.random.key(0), _tree())
        trees[dtype] = info["payload_bytes_per_client"]
    c = D_FLAT // D_BLOCK
    assert trees["float32"] == c * k * 4
    assert trees["bfloat16"] == c * k * 2
    # int8: 1 byte per value + one f32 scale per chunk
    assert trees["int8"] == c * (k + 4)
    assert trees["float32"] / trees["int8"] > 3.5  # ~4x fewer bytes


def test_sparse_proj_ledger_honest_across_densities():
    """SparseProj's density ``s`` is a server-side reconstruction parameter,
    never a wire one: clients running heterogeneous densities declare and
    ship IDENTICAL byte counts (the column draws are key-derived, only the k
    values travel), and every payload matches its declared schema exactly."""
    key = jax.random.key(0)
    rng = np.random.default_rng(3)
    c = D_FLAT // D_BLOCK
    x = jnp.asarray(rng.standard_normal((c, D_BLOCK)), jnp.float32)
    sizes = set()
    for client_id, s in enumerate((1.0, 4.0, 16.0, 64.0)):
        pipe = codec.as_pipeline(codec.SparseProj(k=64, d_block=D_BLOCK, s=s))
        payload = pipe.encode_payload(key, client_id, x)
        assert codec.check_against_schema(payload) == []
        assert payload.nbytes == pipe.payload_nbytes(c)
        sizes.add(payload.nbytes)
    assert len(sizes) == 1, sizes


def test_sparse_proj_est_mode_declares_aux_norms():
    """r_mode='est' ships one f32 norm per chunk on top of the k values —
    the declared ledger must charge it, not hide it."""
    key = jax.random.key(1)
    rng = np.random.default_rng(4)
    c = D_FLAT // D_BLOCK
    x = jnp.asarray(rng.standard_normal((c, D_BLOCK)), jnp.float32)
    fixed = codec.as_pipeline(codec.SparseProj(k=64, d_block=D_BLOCK))
    est = codec.as_pipeline(codec.SparseProj(k=64, d_block=D_BLOCK,
                                             r_mode="est"))
    payload = est.encode_payload(key, 0, x)
    assert codec.check_against_schema(payload) == []
    assert payload.nbytes == est.payload_nbytes(c)
    assert est.payload_nbytes(c) == fixed.payload_nbytes(c) + c * 4
