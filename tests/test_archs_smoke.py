"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + train-grad step + one-token decode on CPU. Asserts shapes + no
NaNs. Full-size configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    param_axes,
    param_defs,
)

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _batch(cfg, key):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.fixture(scope="module", params=configs.ARCHS)
def arch_setup(request):
    cfg = configs.reduce_for_smoke(configs.get_config(request.param))
    params = init_params(cfg, jax.random.key(0))
    return request.param, cfg, params


def test_param_tree_matches_abstract(arch_setup):
    _, cfg, params = arch_setup
    sds = abstract_params(cfg)
    real = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, real, sds))
    axes = param_axes(cfg)
    jax.tree.map(
        lambda x, ax: None if len(ax) == x.ndim else pytest.fail(f"{x.shape} vs {ax}"),
        params, axes,
    )


def test_forward_shapes_no_nans(arch_setup):
    name, cfg, params = arch_setup
    batch = _batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, t: forward(p, cfg, t))(params, batch["inputs"])
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), name
    assert bool(jnp.isfinite(aux)), name


def test_train_grad_step(arch_setup):
    name, cfg, params = arch_setup
    batch = _batch(cfg, jax.random.key(2))

    @jax.jit
    def step(p, b):
        (loss, m), g = jax.value_and_grad(lambda pp: loss_fn(pp, cfg, b), has_aux=True)(p)
        gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g)))
        return loss, gnorm

    loss, gnorm = step(params, batch)
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm)), name
    assert float(loss) > 0
    assert float(gnorm) > 0


def test_decode_step(arch_setup):
    name, cfg, params = arch_setup
    cache = init_cache(cfg, B, seq_len=16)
    if cfg.input_mode == "tokens":
        tok = jnp.array([[1], [2]], jnp.int32)
    else:
        tok = jnp.ones((B, 1, cfg.d_model), jnp.float32)
    pos = jnp.full((B, 1), 3, jnp.int32)
    logits, new_cache = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q))(
        params, cache, tok, pos
    )
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), name
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_prefill_decode_consistency(arch_setup):
    """Greedy logits from full forward at position t == decode-step logits
    after feeding tokens 0..t through the cache path."""
    name, cfg, params = arch_setup
    if cfg.input_mode != "tokens":
        pytest.skip("embeddings-input stub")
    t = 6
    toks = jax.random.randint(jax.random.key(3), (1, t + 1), 0, cfg.vocab_size)
    full_logits, _ = forward(params, cfg, toks)
    cache = init_cache(cfg, 1, seq_len=16)
    logits = None
    for i in range(t + 1):
        logits, cache = decode_step(
            params, cfg, cache, toks[:, i : i + 1], jnp.full((1, 1), i, jnp.int32)
        )
    np.testing.assert_allclose(
        np.asarray(logits[0, 0]), np.asarray(full_logits[0, t]), rtol=5e-2, atol=5e-3
    )


def test_full_config_param_counts():
    """Full configs instantiate abstractly (no allocation) with sane sizes."""
    expect_b = {
        "qwen1.5-32b": (28, 36),
        "deepseek-67b": (62, 72),
        "deepseek-coder-33b": (30, 36),
        "gemma3-4b": (3, 5.5),
        "musicgen-medium": (1.3, 2.2),
        "deepseek-moe-16b": (14, 19),
        "mixtral-8x22b": (130, 150),
        "llava-next-34b": (32, 37),
        "mamba2-130m": (0.1, 0.2),
        "jamba-v0.1-52b": (47, 58),
    }
    for name in configs.ARCHS:
        cfg = configs.get_config(name)
        n = cfg.n_params() / 1e9
        lo, hi = expect_b[name]
        assert lo <= n <= hi, f"{name}: {n:.2f}B params out of [{lo},{hi}]"
        if cfg.n_experts:
            assert cfg.n_params_active() < cfg.n_params()
