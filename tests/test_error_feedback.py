"""Error feedback: residual correctness and the classic EF guarantee that
accumulated Top-k error stays bounded (contraction) while plain Top-k mean
drifts on adversarial inputs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.dist import collectives


def test_ef_residual_is_input_minus_self_decode():
    n, d, k = 3, 64, 8
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    spec = codec.build("top_k", k=k, d_block=d, ef=True)
    ef0 = jnp.zeros((n, 1, d))
    mean, info, ef1 = collectives.compressed_mean_tree(
        spec, jax.random.key(0), tree, ef_chunks=ef0
    )
    # residual support is exactly the non-top-k coordinates of the input
    x = np.asarray(tree["w"])
    for i in range(n):
        r = np.asarray(ef1[i, 0])
        kept = np.argsort(-np.abs(x[i]))[:k]
        assert np.allclose(r[kept], 0, atol=1e-6)
        mask = np.ones(d, bool)
        mask[kept] = False
        np.testing.assert_allclose(r[mask], x[i][mask], rtol=1e-6)


def test_ef_accumulates_missed_mass_over_rounds():
    """A coordinate always below the top-k threshold is eventually
    transmitted under EF (residual growth promotes it); without EF it never
    is. This is the compressed-SGD convergence mechanism."""
    n, d, k = 2, 32, 4
    base = np.zeros(d, np.float32)
    base[:k] = 3.0       # dominant coords hog top-k
    base[k] = 1.0        # persistently-missed coordinate; residual grows +1/round
    tree = {"w": jnp.asarray(np.tile(base, (n, 1)))}
    spec = codec.build("top_k", k=k, d_block=d, ef=True)
    ef = jnp.zeros((n, 1, d))
    seen = 0.0
    for t in range(8):
        mean, _, ef = collectives.compressed_mean_tree(
            spec, jax.random.fold_in(jax.random.key(1), t), tree, ef_chunks=ef
        )
        seen += float(mean["w"][k])
    assert seen > 0.5, "EF never flushed the missed coordinate"
    # without EF the coordinate is never transmitted
    mean_plain, _, _ = collectives.compressed_mean_tree(spec, jax.random.key(2), tree)
    assert float(mean_plain["w"][k]) == 0.0


def test_shardmap_ef_matches_gspmd():
    """ROADMAP item: EF under the shard_map path, residuals shard-local.

    Multi-round parity: identical keys => identical payloads => the shard_map
    mean AND residual trajectories must match the GSPMD path to float
    tolerance, for a biased codec (top_k) and an unbiased one
    (rand_proj_spatial via its (d/k) G^T z self-decode)."""
    n, d, k = 4, 64, 8
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    mesh = jax.make_mesh((1,), ("pod",))
    for name in ("top_k", "rand_proj_spatial"):
        spec = codec.build(name, k=k, d_block=d, ef=True,
                             use_pallas="never")
        ef_a = ef_b = jnp.zeros((n, 1, d))
        for t in range(3):
            key = jax.random.fold_in(jax.random.key(5), t)
            mean_a, _, ef_a = collectives.compressed_mean_tree(
                spec, key, tree, ef_chunks=ef_a
            )
            mean_b, _, ef_b = collectives.compressed_mean_tree_shardmap(
                spec, key, tree, mesh, ef_chunks=ef_b
            )
            np.testing.assert_allclose(
                np.asarray(mean_a["w"]), np.asarray(mean_b["w"]),
                rtol=1e-5, atol=1e-5, err_msg=f"{name} round {t} mean",
            )
            np.testing.assert_allclose(
                np.asarray(ef_a), np.asarray(ef_b), rtol=1e-5, atol=1e-5,
                err_msg=f"{name} round {t} residual",
            )


def test_shardmap_ef_with_partial_participation():
    """Non-participants' residuals must carry over unchanged on both paths."""
    n, d, k = 4, 64, 8
    rng = np.random.default_rng(4)
    tree = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    mesh = jax.make_mesh((1,), ("pod",))
    spec = codec.build("top_k", k=k, d_block=d, ef=True)
    ef0 = jnp.asarray(rng.standard_normal((n, 1, d)), jnp.float32)
    surv = np.array([0, 2])
    mean_a, _, ef_a = collectives.compressed_mean_tree(
        spec, jax.random.key(6), tree, ef_chunks=ef0, participants=surv
    )
    mean_b, _, ef_b = collectives.compressed_mean_tree_shardmap(
        spec, jax.random.key(6), tree, mesh, ef_chunks=ef0, participants=surv
    )
    np.testing.assert_allclose(np.asarray(mean_a["w"]), np.asarray(mean_b["w"]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ef_a), np.asarray(ef_b),
                               rtol=1e-5, atol=1e-5)
    for i in (1, 3):  # dropped clients: untouched residuals
        np.testing.assert_array_equal(np.asarray(ef_a[i]), np.asarray(ef0[i]))
