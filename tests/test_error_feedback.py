"""Error feedback: residual correctness and the classic EF guarantee that
accumulated Top-k error stays bounded (contraction) while plain Top-k mean
drifts on adversarial inputs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EstimatorSpec
from repro.dist import collectives


def test_ef_residual_is_input_minus_self_decode():
    n, d, k = 3, 64, 8
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((n, d)), jnp.float32)}
    spec = EstimatorSpec(name="top_k", k=k, d_block=d, ef=True)
    ef0 = jnp.zeros((n, 1, d))
    mean, info, ef1 = collectives.compressed_mean_tree(
        spec, jax.random.key(0), tree, ef_chunks=ef0
    )
    # residual support is exactly the non-top-k coordinates of the input
    x = np.asarray(tree["w"])
    for i in range(n):
        r = np.asarray(ef1[i, 0])
        kept = np.argsort(-np.abs(x[i]))[:k]
        assert np.allclose(r[kept], 0, atol=1e-6)
        mask = np.ones(d, bool)
        mask[kept] = False
        np.testing.assert_allclose(r[mask], x[i][mask], rtol=1e-6)


def test_ef_accumulates_missed_mass_over_rounds():
    """A coordinate always below the top-k threshold is eventually
    transmitted under EF (residual growth promotes it); without EF it never
    is. This is the compressed-SGD convergence mechanism."""
    n, d, k = 2, 32, 4
    base = np.zeros(d, np.float32)
    base[:k] = 3.0       # dominant coords hog top-k
    base[k] = 1.0        # persistently-missed coordinate; residual grows +1/round
    tree = {"w": jnp.asarray(np.tile(base, (n, 1)))}
    spec = EstimatorSpec(name="top_k", k=k, d_block=d, ef=True)
    ef = jnp.zeros((n, 1, d))
    seen = 0.0
    for t in range(8):
        mean, _, ef = collectives.compressed_mean_tree(
            spec, jax.random.fold_in(jax.random.key(1), t), tree, ef_chunks=ef
        )
        seen += float(mean["w"][k])
    assert seen > 0.5, "EF never flushed the missed coordinate"
    # without EF the coordinate is never transmitted
    mean_plain, _, _ = collectives.compressed_mean_tree(spec, jax.random.key(2), tree)
    assert float(mean_plain["w"][k]) == 0.0
