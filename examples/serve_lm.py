"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve

argv = sys.argv[1:] or [
    "--arch", "gemma3-4b", "--preset", "tiny",
    "--batch", "4", "--prompt-len", "32", "--gen", "16",
]
serve.main(argv)
print("OK: batched prefill+decode served.")
