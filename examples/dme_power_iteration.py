"""Paper §5 experiment: distributed power iteration with compressed means.

    PYTHONPATH=src python examples/dme_power_iteration.py [--noniid]

Reproduces the structure of Fig. 4 (top row): n=10 clients hold shards of an
image-like dataset (synthetic stand-in for Fashion-MNIST, d=1024); each
round every client runs one local power iteration and sends a k=102
compressed eigvector estimate; the server's estimate converges toward the
true principal eigenvector. Rand-Proj-Spatial(Avg) converges closest.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EstimatorSpec, mean_estimate

ap = argparse.ArgumentParser()
ap.add_argument("--noniid", action="store_true")
ap.add_argument("--iters", type=int, default=15)
args = ap.parse_args()

n, d, k = 10, 1024, 102
rng = np.random.default_rng(0)
rank = 16
basis = rng.standard_normal((rank, d)) / np.sqrt(d)
z = rng.standard_normal((4000, rank)) * np.geomspace(3, 0.3, rank)
labels = rng.integers(0, 10, 4000)
shift = rng.standard_normal((10, d)) * 0.4 / np.sqrt(d)
x = (z @ basis + shift[labels] + 0.05 * rng.standard_normal((4000, d))).astype(np.float32)
if args.noniid:
    x = x[np.argsort(labels)]
shards = jnp.asarray(x.reshape(n, -1, d))
v_top = np.linalg.eigh(x.T @ x / len(x))[1][:, -1]

for name, kw in [
    ("identity", {}), ("rand_k", {}), ("rand_k_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="avg")), ("wangni", {}), ("induced", {}),
]:
    spec = EstimatorSpec(name=name, k=k, d_block=d, **kw)

    @jax.jit
    def rnd(v, key):
        local = jnp.einsum("nmd,d->nm", shards, v)
        vi = jnp.einsum("nmd,nm->nd", shards, local)
        vi = vi / (jnp.linalg.norm(vi, axis=1, keepdims=True) + 1e-9)
        vh = mean_estimate(spec, key, vi[:, None, :])[0]
        return vh / (jnp.linalg.norm(vh) + 1e-9)

    v = jnp.ones(d) / jnp.sqrt(d)
    for t in range(args.iters):
        v = rnd(v, jax.random.fold_in(jax.random.key(7), t))
    err = min(float(jnp.linalg.norm(v - v_top)), float(jnp.linalg.norm(v + v_top)))
    print(f"  {name:20s} ||v - v_top|| = {err:.4f}")
