"""Paper §5 experiment: distributed power iteration with compressed means.

    PYTHONPATH=src python examples/dme_power_iteration.py [--noniid] [--iters N]

Reproduces the structure of Fig. 4 (top row) on the repro.fl round
orchestration: n=10 clients hold shards of an image-like dataset (synthetic
stand-in for Fashion-MNIST, d=1024); each round every client runs one local
power iteration and sends a k=102 compressed eigvector estimate; the server's
estimate converges toward the true principal eigenvector.
Rand-Proj-Spatial(Avg) converges closest; the byte column makes the wire cost
explicit — the rand_k / rand_k_spatial / rand_proj_spatial family pays
identical bytes (k values, indices key-derived), wangni/induced additionally
transmit data-dependent indices, and identity is the uncompressed baseline.
"""
import argparse

from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

ap = argparse.ArgumentParser()
ap.add_argument("--noniid", action="store_true")
ap.add_argument("--iters", type=int, default=15)
args = ap.parse_args()

n, d, k = 10, 1024, 102
task = get_task(
    "power_iteration", n_clients=n, d=d, samples=4000,
    scheme="band" if args.noniid else "iid",
)
cohort = Cohort(n_clients=n)

for name, kw in [
    ("identity", {}), ("rand_k", {}), ("rand_k_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="avg")), ("wangni", {}), ("induced", {}),
]:
    spec = codec.build(name, k=k, d_block=d, **kw)
    state, hist = run_rounds(task, spec, cohort, RoundConfig(n_rounds=args.iters))
    err = task.metric(state)
    print(f"  {name:20s} ||v - v_top|| = {err:.4f}   "
          f"bytes = {hist.total_bytes}")
