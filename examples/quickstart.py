"""Quickstart: correlation-aware sparsified mean estimation in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Eight clients hold correlated 1024-dim vectors; each may send only k=64
numbers. Rand-Proj-Spatial (this paper) beats Rand-k and Rand-k-Spatial by
using SRHT projections + correlation-aware spectral decoding.

``codec.build(name, **kwargs)`` is the keyword-compatible constructor for
the composable pipeline API; hand-composed ``codec.Pipeline([...])`` stages
are equivalent — see examples/fl_logistic.py and the README quickstart.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec, correlation

n, d, k = 8, 1024, 64
rng = np.random.default_rng(0)
shared = rng.standard_normal(d)
xs = jnp.asarray(
    np.stack([shared + 0.3 * rng.standard_normal(d) for _ in range(n)])[:, None, :],
    jnp.float32,
)  # (n, chunks=1, d): highly correlated clients
xbar = jnp.mean(xs, axis=0)
r = float(correlation.r_exact(xs))
print(f"n={n} d={d} k={k}  (compression {d // k}x)  correlation R={r:.2f} of max {n - 1}")

for name, kwargs in [
    ("rand_k", {}),
    ("rand_k_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="avg")),
    ("rand_proj_spatial", dict(transform="opt", r_mode="est")),  # online R-hat (ours)
]:
    pipe = codec.build(name, k=k, d_block=d, **kwargs)
    fn = jax.jit(lambda key: correlation.mse(pipe.mean_estimate(key, xs), xbar))
    mses = jax.lax.map(fn, jax.random.split(jax.random.key(1), 100))
    label = name + ("(" + kwargs.get("transform", "") + ("/est" if kwargs.get("r_mode") == "est" else "") + ")")
    print(f"  {label:38s} MSE = {float(jnp.mean(mses)):.4f}")
