"""Federated logistic regression over a non-IID 10-client cohort.

    PYTHONPATH=src python examples/fl_logistic.py [--smoke]

The README quickstart for the repro.fl subsystem: 10 clients hold
Dirichlet(0.3)-skewed class mixtures of a gaussian-blob classification
problem; 80% of clients are sampled each round and 10% of those drop out
(stragglers). Gradients cross the wire through Rand-Proj-Spatial with the
practical wavg transform (the server tracks cross-client correlation online —
no oracle R). The final table compares MSE-at-equal-bytes against the
Rand-k / Rand-k-Spatial baselines.

The last row decodes gradient deltas against the server's previous estimate
(temporal mode) — shown for completeness, and expect it to LOSE here:
converging SGD gradients shrink and rotate every round, so the previous
gradient mean is poor side information (||x - side|| > ||x||). Temporal
decoding pays off on slowly-drifting targets — see the `drift` task
(`python -m repro.fl.run --task drift --temporal`) and
tests/test_fl.py::test_temporal_beats_spatial_on_drift.
"""
import argparse

import numpy as np

from repro.core import codec
from repro.fl import Cohort, RoundConfig, get_task, run_rounds

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
ap.add_argument("--rounds", type=int, default=0, help="0 => 5 smoke / 40 full")
args = ap.parse_args()

n = 10
feat, samples = (16, 400) if args.smoke else (64, 4000)
rounds = args.rounds or (5 if args.smoke else 40)

task = get_task("logistic_regression", n_clients=n, feat=feat, samples=samples,
                scheme="dirichlet", alpha=0.3)
cohort = Cohort(n_clients=n, participation=0.8, dropout=0.1)
d_block = 1 << (task.dim - 1).bit_length()
k = max(4, d_block // 10)

print(f"10-client federated logistic regression: dim={task.dim}, "
      f"d_block={d_block}, k={k}, {rounds} rounds, Dirichlet(0.3) non-IID")
for label, name, kw, temporal in [
    ("rand_k", "rand_k", {}, False),
    ("rand_k_spatial(avg)", "rand_k_spatial", dict(transform="avg"), False),
    ("rand_proj_spatial(wavg)", "rand_proj_spatial", dict(transform="wavg"), False),
    # expected to lose here — see docstring; kept as the honest counterpoint
    ("rand_proj_spatial(wavg)+temporal", "rand_proj_spatial",
     dict(transform="wavg"), True),
]:
    spec = codec.build(name, k=k, d_block=d_block, **kw)
    cfg = RoundConfig(n_rounds=rounds, temporal=temporal)
    state, hist = run_rounds(task, spec, cohort, cfg)
    acc = task.aux["accuracy"](state)
    print(f"  {label:34s} xent={task.metric(state):.4f}  acc={acc:.4f}  "
          f"mean_grad_mse={np.nanmean(hist.mse):.6f}  bytes={hist.total_bytes}")
