"""End-to-end driver: train an LM with compressed cross-client gradient
aggregation, fault-tolerant supervisor, checkpoints and resume.

    # ~2 min on CPU (tiny mamba2):
    PYTHONPATH=src python examples/train_lm.py

    # ~100M-parameter run of the paper-scale example (real hardware):
    PYTHONPATH=src python examples/train_lm.py --preset small --arch mamba2-130m \
        --steps 300 --batch 8 --seq 512

This is a thin veneer over repro.launch.train (the production CLI); it also
demonstrates failure injection + elastic client resize in one run.
"""
import sys

from repro.launch import train

argv = sys.argv[1:]
if not argv:
    argv = [
        "--arch", "mamba2-130m", "--preset", "tiny", "--steps", "120",
        "--clients", "4", "--k", "32", "--d-block", "256",
        "--estimator", "rand_proj_spatial",
        "--ckpt-every", "40", "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--inject-failures", "60",      # simulated node failure -> auto-restore
        "--resize", "90:2",             # elastic: 4 -> 2 clients mid-run
    ]
history = train.main(argv)
assert history and history[-1][1] < history[0][1], "loss should decrease"
print("OK: loss decreased through failure + elastic resize.")
